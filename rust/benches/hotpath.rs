//! Bench target: hot-path microbenchmarks — the §Perf iteration harness.
//!
//! Covers every layer the perf pass optimizes:
//!   L3 rust: batched multi-stream engine (streams/sec at B ∈ {1,4,8,32}
//!            vs the seed's naive batch-1 scalar loop), PJRT inference
//!            (small + nominal), pure-rust f32 forward, fixed-point
//!            forward, cycle-simulator throughput, DSE speed, window
//!            generation (FFT + filters), router dispatch.
//!
//! Every measurement is also written to `BENCH_hotpath.json`
//! (name -> median ns/op, plus derived per-stream throughput keys) so later
//! PRs have a machine-readable perf baseline to diff against.
//!
//! Run: `cargo bench --bench hotpath` (artifact-dependent sections skip
//! gracefully). Set `GWLSTM_BENCH_SMOKE=1` for a tiny-iteration smoke run
//! (used by ci.sh so the bench code can't silently rot).

use std::collections::BTreeMap;

use gwlstm::config::Manifest;
use gwlstm::coordinator::router::{Job, Router};
use gwlstm::gw::dataset::{StrainStream, DEFAULT_SNR};
use gwlstm::gw::fft::Plan;
use gwlstm::gw::psd::colored_noise;
use gwlstm::hls::device::Device;
use gwlstm::hls::dse::partition_model;
use gwlstm::hls::perf_model::{DesignPoint, LayerDims};
use gwlstm::model::{
    forward_f32, AutoencoderWeights, FixedAutoencoder, PackedAutoencoder,
};
use gwlstm::runtime::{Engine, ModelExecutor};
use gwlstm::sim::{simulate, SimConfig};
use gwlstm::util::bench::Bench;
use gwlstm::util::json::Value;
use gwlstm::util::rng::Rng;

/// Collected results: bench name -> median ns per op.
struct Recorder {
    out: BTreeMap<String, Value>,
    smoke: bool,
}

impl Recorder {
    fn new() -> Recorder {
        Recorder {
            out: BTreeMap::new(),
            smoke: std::env::var("GWLSTM_BENCH_SMOKE").is_ok(),
        }
    }

    /// Scale iteration counts down to a smoke-test budget when asked.
    fn iters(&self, n: usize) -> usize {
        if self.smoke {
            2
        } else {
            n
        }
    }

    fn put(&mut self, name: &str, median_ns: f64) {
        self.out.insert(name.to_string(), Value::Num(median_ns));
    }

    fn flush(&self) {
        let doc = Value::Obj(self.out.clone());
        let path = "BENCH_hotpath.json";
        match std::fs::write(path, doc.to_string()) {
            Ok(()) => println!("\nwrote {} entries to {path}", self.out.len()),
            Err(e) => eprintln!("could not write {path}: {e}"),
        }
    }
}

fn main() {
    let mut rec = Recorder::new();

    // ---- batched multi-stream engine (no artifacts needed) ----
    // The tentpole measurement: per-stream throughput of the packed/tiled
    // lockstep engine at B ∈ {1, 4, 8, 32} against the seed's batch-1
    // scalar loop (naive triple-loop weight walk per stream).
    let ts = 100usize;
    let weights = AutoencoderWeights::synthetic(0xBA7C, "nominal");
    let packed = PackedAutoencoder::from_weights(&weights);
    let mut stream = StrainStream::new(9, ts, DEFAULT_SNR, 0.3);
    let max_b = 32usize;
    let mut pool: Vec<f32> = Vec::with_capacity(max_b * ts);
    for _ in 0..max_b {
        pool.extend_from_slice(&stream.next_window().samples);
    }

    let seq = Bench::new("batched: scalar batch-1 loop x8 (seed engine)")
        .iters(rec.iters(30))
        .run(|| {
            for b in 0..8 {
                std::hint::black_box(forward_f32(&weights, &pool[b * ts..(b + 1) * ts]));
            }
        });
    let seq_per_stream = seq.median_ns / 8.0;
    rec.put("batched/scalar_seq_x8_per_stream", seq_per_stream);
    println!(
        "  -> scalar batch-1 loop: {:.0} ns/stream ({:.0} streams/s)",
        seq_per_stream,
        1e9 / seq_per_stream
    );

    let mut b8_per_stream = f64::NAN;
    for &b in &[1usize, 4, 8, 32] {
        let st = Bench::new(&format!("batched: packed lockstep B={b}"))
            .iters(rec.iters(30))
            .run(|| {
                std::hint::black_box(packed.forward_batch(&pool[..b * ts], b));
            });
        let per_stream = st.median_ns / b as f64;
        rec.put(&format!("batched/packed_b{b}_per_stream"), per_stream);
        println!(
            "  -> B={b}: {:.0} ns/stream ({:.0} streams/s)",
            per_stream,
            1e9 / per_stream
        );
        if b == 8 {
            b8_per_stream = per_stream;
        }
    }
    let speedup = seq_per_stream / b8_per_stream;
    rec.put("batched/speedup_b8_vs_scalar_seq", speedup);
    println!(
        "  -> per-stream speedup @ B=8 vs seed batch-1 loop: {speedup:.2}x \
         (acceptance floor 1.5x)"
    );

    // Executor-level dispatch cost: the serving coordinator's view (one
    // score_batch call vs a loop of score calls, native backend).
    let exe = ModelExecutor::native_from_weights(&weights, "nominal_synth", ts);
    let st = Bench::new("executor: score() x8 batch-1 loop")
        .iters(rec.iters(20))
        .run(|| {
            for b in 0..8 {
                std::hint::black_box(exe.score(&pool[b * ts..(b + 1) * ts]).unwrap());
            }
        });
    rec.put("executor/score_x8_per_stream", st.median_ns / 8.0);
    let st = Bench::new("executor: score_batch(B=8) one call")
        .iters(rec.iters(20))
        .run(|| {
            std::hint::black_box(exe.score_batch(&pool[..8 * ts], 8).unwrap());
        });
    rec.put("executor/score_batch_b8_per_stream", st.median_ns / 8.0);

    // ---- simulator & DSE (no artifacts needed) ----
    let u250 = Device::by_name("u250").unwrap();
    let point = DesignPoint::nominal_autoencoder(9, 1, 8);
    let st = Bench::new("cycle-sim: nominal x128 inferences")
        .iters(rec.iters(50))
        .run(|| {
            let r = simulate(&SimConfig {
                point: point.clone(),
                device: *u250,
                inferences: 128,
                arrival_interval: None,
                rewind: true,
                overlap: true,
            });
            std::hint::black_box(r.makespan);
        });
    rec.put("sim/nominal_x128", st.median_ns);
    // simulated-cycles per wall-second (the §Perf L3 target metric)
    let sim_cycles = {
        let r = simulate(&SimConfig {
            point: point.clone(),
            device: *u250,
            inferences: 128,
            arrival_interval: None,
            rewind: true,
            overlap: true,
        });
        r.makespan as f64
    };
    println!(
        "  -> simulator speed: {:.1} M simulated cycles / s",
        sim_cycles / (st.median_ns / 1e9) / 1e6
    );

    let layers = vec![
        LayerDims::new(1, 32),
        LayerDims::new(32, 8),
        LayerDims::new(8, 8),
        LayerDims::new(8, 32),
    ];
    let st = Bench::new("DSE: partition nominal @ 2800 DSPs")
        .iters(rec.iters(200))
        .run(|| {
            let p = partition_model(u250, &layers, 8, 1, 2_800);
            std::hint::black_box(p.perf.dsp_model);
        });
    rec.put("dse/partition_nominal", st.median_ns);

    // ---- GW substrate ----
    let plan = Plan::new(2048);
    let mut rng = Rng::new(0);
    let st = Bench::new("gw: colored_noise 2048 samples")
        .iters(rec.iters(100))
        .run(|| {
            std::hint::black_box(colored_noise(&mut rng, &plan, 2048.0));
        });
    rec.put("gw/colored_noise_2048", st.median_ns);
    let mut stream = StrainStream::new(1, 100, DEFAULT_SNR, 0.3);
    let st = Bench::new("gw: StrainStream next_window (TS=100)")
        .iters(rec.iters(100))
        .run(|| {
            std::hint::black_box(stream.next_window());
        });
    rec.put("gw/next_window_ts100", st.median_ns);

    // ---- router dispatch (queue cost only) ----
    let st = Bench::new("router: dispatch+drain 1024 jobs x4 workers")
        .iters(rec.iters(50))
        .run(|| {
            let (router, queues) = Router::new(4, 512);
            for seq in 0..1024u64 {
                let _ = router.route(Job { seq, payload: seq });
            }
            router.shutdown();
            let mut got = 0;
            for q in &queues {
                while q.recv().is_some() {
                    got += 1;
                }
            }
            std::hint::black_box(got);
        });
    rec.put("router/dispatch_drain_1024x4", st.median_ns);

    // ---- fixed-point datapath (no artifacts needed) ----
    let fixed = FixedAutoencoder::from_weights(&weights);
    let st = Bench::new("rust q16: nominal_ts100 forward")
        .iters(rec.iters(50))
        .run(|| {
            std::hint::black_box(fixed.forward(&pool[..ts]));
        });
    rec.put("model/q16_forward_ts100", st.median_ns);
    let st = Bench::new("rust q16: lockstep forward_batch B=8")
        .iters(rec.iters(20))
        .run(|| {
            std::hint::black_box(fixed.forward_batch(&pool[..8 * ts], 8));
        });
    rec.put("model/q16_forward_batch_b8_per_stream", st.median_ns / 8.0);

    // ---- PJRT datapath (artifacts required) ----
    'pjrt: {
        let Ok(manifest) = Manifest::load("artifacts") else {
            eprintln!("artifacts/ missing — PJRT datapath benches skipped");
            break 'pjrt;
        };
        let Ok(engine) = Engine::cpu() else {
            eprintln!("PJRT client unavailable — PJRT benches skipped");
            break 'pjrt;
        };
        let (Ok(small), Ok(nominal)) = (
            engine.load_variant(&manifest, "small_ts8"),
            engine.load_variant(&manifest, "nominal_ts100"),
        ) else {
            eprintln!("PJRT compile unavailable (offline xla shim) — PJRT benches skipped");
            break 'pjrt;
        };

        let mut s8 = StrainStream::new(2, 8, DEFAULT_SNR, 0.0);
        let w8 = s8.next_window();
        let mut s100 = StrainStream::new(3, 100, DEFAULT_SNR, 0.0);
        let w100 = s100.next_window();

        let st = Bench::new("PJRT: small_ts8 batch-1 infer")
            .warmup(10)
            .iters(rec.iters(200))
            .run(|| {
                std::hint::black_box(small.infer(&w8.samples).unwrap());
            });
        rec.put("pjrt/small_ts8_infer", st.median_ns);
        let st = Bench::new("PJRT: nominal_ts100 batch-1 infer")
            .warmup(10)
            .iters(rec.iters(100))
            .run(|| {
                std::hint::black_box(nominal.infer(&w100.samples).unwrap());
            });
        rec.put("pjrt/nominal_ts100_infer", st.median_ns);
    }

    let st = Bench::new("rust f32: nominal_ts100 forward (scalar)")
        .iters(rec.iters(100))
        .run(|| {
            std::hint::black_box(forward_f32(&weights, &pool[..ts]));
        });
    rec.put("model/f32_forward_ts100", st.median_ns);

    rec.flush();
}
