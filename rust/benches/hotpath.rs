//! Bench target: hot-path microbenchmarks — the §Perf iteration harness.
//!
//! Covers every layer the perf pass optimizes:
//!   L3 rust: batched multi-stream engine (streams/sec at B ∈ {1,4,8,32}
//!            vs the seed's naive batch-1 scalar loop AND vs the frozen
//!            PR 1 engine), the FastSimd math tier, the streaming state
//!            service (stateful continuation per hop of new samples vs
//!            re-encoding the full window from zeros — the `stream/*`
//!            keys), the balanced-partition parallel layer (thread scaling
//!            at B=32 and the balanced-vs-naive split comparison — the
//!            `par/*` keys, parity-guarded before timing),
//!            PJRT inference (small + nominal), pure-rust f32
//!            forward, fixed-point forward, cycle-simulator throughput,
//!            DSE speed, window generation (FFT + filters), router
//!            dispatch.
//!
//! Two JSON files are written per run, so the before/after perf claim is
//! always a same-machine, same-build comparison:
//!   * `BENCH_hotpath.json` — the current engine (BitExact + FastSimd),
//!     with derived per-stream throughput, GFLOP/s, and speedup keys;
//!   * `BENCH_hotpath_pr1_baseline.json` — the PR 1 hot path, re-measured
//!     from the implementation frozen verbatim in
//!     `model::batched::reference`.
//!
//! The run also self-checks the FastSimd contract: if fast scores diverge
//! from BitExact beyond `model::simd::FAST_FORWARD_TOL` the process exits
//! nonzero (ci.sh runs this as a smoke test, so a tolerance regression
//! fails CI, not just a nightly bench).
//!
//! Run: `cargo bench --bench hotpath` (artifact-dependent sections skip
//! gracefully). Set `GWLSTM_BENCH_SMOKE=1` for a tiny-iteration smoke run.

use std::collections::BTreeMap;

use gwlstm::config::Manifest;
use gwlstm::coordinator::router::{Job, Router};
use gwlstm::gw::dataset::{StrainStream, DEFAULT_SNR};
use gwlstm::gw::fft::Plan;
use gwlstm::gw::psd::colored_noise;
use gwlstm::hls::device::Device;
use gwlstm::hls::dse::partition_model;
use gwlstm::hls::perf_model::{DesignPoint, LayerDims};
use gwlstm::model::act_lut::SigmoidLut;
use gwlstm::model::batched::reference;
use gwlstm::model::fixed::{fused_gate_tail, gate_tail_f32_reference, to_q16, PackedMatrixI16};
use gwlstm::model::simd::FAST_FORWARD_TOL;
use gwlstm::model::{
    forward_f32, AutoencoderWeights, FixedAutoencoder, FixedPackedAutoencoder, MathPolicy,
    PackedAutoencoder, PlanMode, WorkerPool, QUANT_SCORE_TOL,
};
use gwlstm::runtime::{Engine, ModelExecutor};
use gwlstm::sim::{simulate, SimConfig};
use gwlstm::util::bench::Bench;
use gwlstm::util::json::Value;
use gwlstm::util::rng::Rng;

/// Collected results: bench name -> median ns per op (plus derived keys).
struct Recorder {
    out: BTreeMap<String, Value>,
    smoke: bool,
}

impl Recorder {
    fn new() -> Recorder {
        Recorder {
            out: BTreeMap::new(),
            smoke: std::env::var("GWLSTM_BENCH_SMOKE").is_ok(),
        }
    }

    /// Scale iteration counts down to a smoke-test budget when asked.
    fn iters(&self, n: usize) -> usize {
        if self.smoke {
            2
        } else {
            n
        }
    }

    fn put(&mut self, name: &str, median_ns: f64) {
        self.out.insert(name.to_string(), Value::Num(median_ns));
    }

    fn note(&mut self, name: &str, text: &str) {
        self.out.insert(name.to_string(), Value::Str(text.to_string()));
    }

    fn flush(&self, path: &str) {
        let doc = Value::Obj(self.out.clone());
        match std::fs::write(path, doc.to_string()) {
            Ok(()) => println!("\nwrote {} entries to {path}", self.out.len()),
            Err(e) => eprintln!("could not write {path}: {e}"),
        }
    }
}

/// FLOPs of one window through the autoencoder: 2 per MAC over the gate
/// MVMs (`Lx·4Lh + Lh·4Lh` MACs per layer-timestep) plus the final dense.
/// Gate nonlinearities are excluded (the conventional GEMM-flops count),
/// so GFLOP/s here measures multiplier saturation, matching how the paper
/// reasons about DSP utilization.
fn autoencoder_flops_per_window(w: &AutoencoderWeights, ts: usize) -> f64 {
    let mut macs = 0u64;
    for l in &w.layers {
        macs += (ts * (l.lx * 4 * l.lh + l.lh * 4 * l.lh)) as u64;
    }
    let last_lh = w.layers.last().map(|l| l.lh).unwrap_or(0);
    macs += (ts * last_lh * w.d_out) as u64;
    2.0 * macs as f64
}

fn main() {
    let mut rec = Recorder::new();
    let mut base = Recorder::new();
    base.note(
        "_meta",
        "PR 1 hot path re-measured from model::batched::reference (frozen \
         verbatim) in the same process/build as BENCH_hotpath.json",
    );

    // ---- batched multi-stream engine (no artifacts needed) ----
    // The tentpole measurement: per-stream throughput of the register-
    // blocked lockstep engine at B ∈ {1, 4, 8, 32} against (a) the seed's
    // batch-1 scalar loop and (b) the frozen PR 1 engine, plus the
    // FastSimd tier at B=8.
    let ts = 100usize;
    let weights = AutoencoderWeights::synthetic(0xBA7C, "nominal");
    let packed = PackedAutoencoder::from_weights(&weights);
    let packed_fast = PackedAutoencoder::from_weights_policy(&weights, MathPolicy::FastSimd);
    let flops = autoencoder_flops_per_window(&weights, ts);
    let mut stream = StrainStream::new(9, ts, DEFAULT_SNR, 0.3);
    let max_b = 32usize;
    let mut pool: Vec<f32> = Vec::with_capacity(max_b * ts);
    for _ in 0..max_b {
        pool.extend_from_slice(&stream.next_window().samples);
    }

    // Contract self-check BEFORE timing anything: FastSimd must stay
    // within its stated tolerance of BitExact on real windows.
    {
        let exact_scores = packed.score_batch(&pool[..8 * ts], 8);
        let fast_scores = packed_fast.score_batch(&pool[..8 * ts], 8);
        let worst = exact_scores
            .iter()
            .zip(&fast_scores)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        if worst > FAST_FORWARD_TOL {
            eprintln!(
                "FATAL: FastSimd diverged from BitExact by {worst} \
                 (tolerance {FAST_FORWARD_TOL}) — math-tier contract broken"
            );
            std::process::exit(1);
        }
        println!("FastSimd vs BitExact score divergence: {worst:.2e} (tol {FAST_FORWARD_TOL:.0e}) — OK");
        rec.put("batched/fast_vs_bitexact_score_maxdiff", worst as f64);
    }

    let seq = Bench::new("batched: scalar batch-1 loop x8 (seed engine)")
        .iters(rec.iters(30))
        .run(|| {
            for b in 0..8 {
                std::hint::black_box(forward_f32(&weights, &pool[b * ts..(b + 1) * ts]));
            }
        });
    let seq_per_stream = seq.median_ns / 8.0;
    rec.put("batched/scalar_seq_x8_per_stream", seq_per_stream);
    base.put("batched/scalar_seq_x8_per_stream", seq_per_stream);
    println!(
        "  -> scalar batch-1 loop: {:.0} ns/stream ({:.0} streams/s)",
        seq_per_stream,
        1e9 / seq_per_stream
    );

    // PR 1 engine (frozen reference), per-stream at the same batch sizes.
    let mut base_b8_per_stream = f64::NAN;
    for &b in &[1usize, 4, 8, 32] {
        let st = Bench::new(&format!("batched: PR1 reference lockstep B={b}"))
            .iters(rec.iters(30))
            .run(|| {
                std::hint::black_box(reference::forward_batch(&packed, &pool[..b * ts], b));
            });
        let per_stream = st.median_ns / b as f64;
        base.put(&format!("batched/packed_b{b}_per_stream"), per_stream);
        println!(
            "  -> PR1 B={b}: {:.0} ns/stream ({:.0} streams/s, {:.2} GFLOP/s)",
            per_stream,
            1e9 / per_stream,
            flops / per_stream
        );
        if b == 8 {
            base_b8_per_stream = per_stream;
        }
    }
    base.put(
        "batched/speedup_b8_vs_scalar_seq",
        seq_per_stream / base_b8_per_stream,
    );
    base.put("batched/packed_b8_gflops", flops / base_b8_per_stream);

    // Current blocked engine, BitExact tier.
    let mut b8_per_stream = f64::NAN;
    for &b in &[1usize, 4, 8, 32] {
        let st = Bench::new(&format!("batched: blocked lockstep B={b} (bitexact)"))
            .iters(rec.iters(30))
            .run(|| {
                std::hint::black_box(packed.forward_batch(&pool[..b * ts], b));
            });
        let per_stream = st.median_ns / b as f64;
        rec.put(&format!("batched/packed_b{b}_per_stream"), per_stream);
        rec.put(&format!("batched/packed_b{b}_gflops"), flops / per_stream);
        println!(
            "  -> B={b}: {:.0} ns/stream ({:.0} streams/s, {:.2} GFLOP/s)",
            per_stream,
            1e9 / per_stream,
            flops / per_stream
        );
        if b == 8 {
            b8_per_stream = per_stream;
        }
    }
    rec.put(
        "batched/speedup_b8_vs_scalar_seq",
        seq_per_stream / b8_per_stream,
    );

    // FastSimd tier at the acceptance batch size.
    let st = Bench::new("batched: blocked lockstep B=8 (fast_simd)")
        .iters(rec.iters(30))
        .run(|| {
            std::hint::black_box(packed_fast.forward_batch(&pool[..8 * ts], 8));
        });
    let fast_b8_per_stream = st.median_ns / 8.0;
    rec.put("batched/fast_b8_per_stream", fast_b8_per_stream);
    rec.put("batched/fast_b8_gflops", flops / fast_b8_per_stream);
    rec.put(
        "batched/fast_vs_bitexact_speedup",
        b8_per_stream / fast_b8_per_stream,
    );
    rec.put(
        "batched/packed_b8_vs_pr1_baseline",
        base_b8_per_stream / b8_per_stream,
    );
    rec.put(
        "batched/fast_b8_vs_pr1_baseline",
        base_b8_per_stream / fast_b8_per_stream,
    );
    println!(
        "  -> fast_simd B=8: {:.0} ns/stream ({:.2} GFLOP/s)\n\
         \x20 -> blocked bitexact vs PR1 @ B=8: {:.2}x\n\
         \x20 -> fast_simd vs bitexact @ B=8:  {:.2}x\n\
         \x20 -> fast_simd vs PR1 @ B=8:       {:.2}x (acceptance floor 1.5x)",
        fast_b8_per_stream,
        flops / fast_b8_per_stream,
        base_b8_per_stream / b8_per_stream,
        b8_per_stream / fast_b8_per_stream,
        base_b8_per_stream / fast_b8_per_stream,
    );

    // ---- streaming: stateful continuation vs re-encode-from-zero ----
    // The continuous-inference workload advances each stream by hop=25 NEW
    // samples per window. Stateful sessions score exactly those 25 samples
    // against resident (h, c); the stateless baseline must re-encode the
    // full ts=100 window from zeros every hop. Same engine, same weights —
    // the measured ratio is the cost of throwing state away (~ts/hop at
    // the GEMM level, minus fixed per-call overhead).
    let hop = 25usize;
    let mut stream_state = packed.zero_state(8);
    let st = Bench::new("stream: stateful continuation hop=25 B=8 (bitexact)")
        .iters(rec.iters(30))
        .run(|| {
            std::hint::black_box(packed.score_batch_stateful(&pool[..8 * hop], 8, &mut stream_state));
        });
    let stateful_per_window = st.median_ns / 8.0;
    rec.put("stream/stateful_hop25_b8_per_window", stateful_per_window);
    let mut stream_state_fast = packed_fast.zero_state(8);
    let st = Bench::new("stream: stateful continuation hop=25 B=8 (fast_simd)")
        .iters(rec.iters(30))
        .run(|| {
            std::hint::black_box(packed_fast.score_batch_stateful(
                &pool[..8 * hop],
                8,
                &mut stream_state_fast,
            ));
        });
    rec.put("stream/stateful_hop25_b8_per_window_fast", st.median_ns / 8.0);
    // the stateless per-window cost at B=8 measured above IS the re-encode
    // baseline (every hop pays the whole window again)
    rec.put("stream/reencode_ts100_b8_per_window", b8_per_stream);
    rec.put(
        "stream/stateful_vs_reencode_speedup",
        b8_per_stream / stateful_per_window,
    );
    println!(
        "  -> streaming: stateful hop={hop} {:.0} ns/window vs re-encode ts={ts} {:.0} ns/window ({:.2}x per hop of new samples)",
        stateful_per_window,
        b8_per_stream,
        b8_per_stream / stateful_per_window,
    );

    // ---- parallel lockstep execution (worker pool + StagePlan) ----
    // Thread scaling of the balanced-partition parallel layer at the wide
    // batch (B=32), plus the balanced-vs-naive split comparison at the
    // plan's motivating shape (B=30 over 8 lanes: naive leaves a 9-row
    // tail on the last worker; balanced keeps every slice at one register
    // block). All engines are BitExact — the parity guard below asserts
    // the parallel outputs are bit-identical before anything is timed.
    let par_b = 32usize;
    let mut t1_per_window = f64::NAN;
    let mut t4_per_window = f64::NAN;
    let par_want = packed.forward_batch(&pool[..par_b * ts], par_b);
    for &threads in &[1usize, 2, 4, 8] {
        let eng = PackedAutoencoder::from_weights_policy_threads(
            &weights,
            MathPolicy::BitExact,
            threads,
        );
        if eng.forward_batch(&pool[..par_b * ts], par_b) != par_want {
            eprintln!(
                "FATAL: {threads}-thread engine diverged from single-thread \
                 — parallel bit-exactness contract broken"
            );
            std::process::exit(1);
        }
        let st = Bench::new(&format!("par: blocked lockstep B={par_b} threads={threads}"))
            .iters(rec.iters(30))
            .run(|| {
                std::hint::black_box(eng.forward_batch(&pool[..par_b * ts], par_b));
            });
        let per_window = st.median_ns / par_b as f64;
        rec.put(&format!("par/threads{threads}_b32_per_window"), per_window);
        println!(
            "  -> threads={threads}: {:.0} ns/window ({:.2} GFLOP/s aggregate)",
            per_window,
            flops / per_window
        );
        if threads == 1 {
            t1_per_window = per_window;
        }
        if threads == 4 {
            t4_per_window = per_window;
        }
    }
    // parallel efficiency at 4 lanes: speedup(4)/4, 1.0 = perfect scaling
    rec.put(
        "par/scaling_efficiency",
        (t1_per_window / t4_per_window) / 4.0,
    );
    println!(
        "  -> scaling: {:.2}x at 4 threads ({:.0}% efficiency)",
        t1_per_window / t4_per_window,
        100.0 * (t1_per_window / t4_per_window) / 4.0
    );
    {
        let imb_b = 30usize; // 8 lanes: naive = 3-row slices + a 9-row tail
        let balanced = PackedAutoencoder::from_weights_policy_pool(
            &weights,
            MathPolicy::BitExact,
            WorkerPool::new(8),
        );
        let naive = PackedAutoencoder::from_weights_policy_pool(
            &weights,
            MathPolicy::BitExact,
            WorkerPool::with_mode(8, PlanMode::NaiveRows),
        );
        let bal = Bench::new("par: balanced split B=30 threads=8")
            .iters(rec.iters(30))
            .run(|| {
                std::hint::black_box(balanced.forward_batch(&pool[..imb_b * ts], imb_b));
            });
        let nai = Bench::new("par: naive floor split B=30 threads=8")
            .iters(rec.iters(30))
            .run(|| {
                std::hint::black_box(naive.forward_batch(&pool[..imb_b * ts], imb_b));
            });
        rec.put(
            "par/balanced_vs_naive_split_speedup",
            nai.median_ns / bal.median_ns,
        );
        println!(
            "  -> balanced vs naive split @ B={imb_b}, 8 threads: {:.2}x \
             (II-style work balancing vs the floor(B/T) tail)",
            nai.median_ns / bal.median_ns
        );
    }

    // Executor-level dispatch cost: the serving coordinator's view (one
    // score_batch call vs a loop of score calls, native backend).
    let exe = ModelExecutor::native_from_weights(&weights, "nominal_synth", ts);
    let st = Bench::new("executor: score() x8 batch-1 loop")
        .iters(rec.iters(20))
        .run(|| {
            for b in 0..8 {
                std::hint::black_box(exe.score(&pool[b * ts..(b + 1) * ts]).unwrap());
            }
        });
    rec.put("executor/score_x8_per_stream", st.median_ns / 8.0);
    let st = Bench::new("executor: score_batch(B=8) one call")
        .iters(rec.iters(20))
        .run(|| {
            std::hint::black_box(exe.score_batch(&pool[..8 * ts], 8).unwrap());
        });
    rec.put("executor/score_batch_b8_per_stream", st.median_ns / 8.0);

    // ---- simulator & DSE (no artifacts needed) ----
    let u250 = Device::by_name("u250").unwrap();
    let point = DesignPoint::nominal_autoencoder(9, 1, 8);
    let st = Bench::new("cycle-sim: nominal x128 inferences")
        .iters(rec.iters(50))
        .run(|| {
            let r = simulate(&SimConfig {
                point: point.clone(),
                device: *u250,
                inferences: 128,
                arrival_interval: None,
                rewind: true,
                overlap: true,
            });
            std::hint::black_box(r.makespan);
        });
    rec.put("sim/nominal_x128", st.median_ns);
    // simulated-cycles per wall-second (the §Perf L3 target metric)
    let sim_cycles = {
        let r = simulate(&SimConfig {
            point: point.clone(),
            device: *u250,
            inferences: 128,
            arrival_interval: None,
            rewind: true,
            overlap: true,
        });
        r.makespan as f64
    };
    println!(
        "  -> simulator speed: {:.1} M simulated cycles / s",
        sim_cycles / (st.median_ns / 1e9) / 1e6
    );

    let layers = vec![
        LayerDims::new(1, 32),
        LayerDims::new(32, 8),
        LayerDims::new(8, 8),
        LayerDims::new(8, 32),
    ];
    let st = Bench::new("DSE: partition nominal @ 2800 DSPs")
        .iters(rec.iters(200))
        .run(|| {
            let p = partition_model(u250, &layers, 8, 1, 2_800);
            std::hint::black_box(p.perf.dsp_model);
        });
    rec.put("dse/partition_nominal", st.median_ns);

    // ---- GW substrate ----
    let plan = Plan::new(2048);
    let mut rng = Rng::new(0);
    let st = Bench::new("gw: colored_noise 2048 samples")
        .iters(rec.iters(100))
        .run(|| {
            std::hint::black_box(colored_noise(&mut rng, &plan, 2048.0));
        });
    rec.put("gw/colored_noise_2048", st.median_ns);
    let mut stream = StrainStream::new(1, 100, DEFAULT_SNR, 0.3);
    let st = Bench::new("gw: StrainStream next_window (TS=100)")
        .iters(rec.iters(100))
        .run(|| {
            std::hint::black_box(stream.next_window());
        });
    rec.put("gw/next_window_ts100", st.median_ns);

    // ---- router dispatch (queue cost only) ----
    let st = Bench::new("router: dispatch+drain 1024 jobs x4 workers")
        .iters(rec.iters(50))
        .run(|| {
            let (router, queues) = Router::new(4, 512);
            for seq in 0..1024u64 {
                let _ = router.route(Job { seq, payload: seq });
            }
            router.shutdown();
            let mut got = 0;
            for q in &queues {
                while q.recv().is_some() {
                    got += 1;
                }
            }
            std::hint::black_box(got);
        });
    rec.put("router/dispatch_drain_1024x4", st.median_ns);

    // ---- fixed-point datapath (no artifacts needed) ----
    let fixed = FixedAutoencoder::from_weights(&weights);
    let st = Bench::new("rust q16: nominal_ts100 forward")
        .iters(rec.iters(50))
        .run(|| {
            std::hint::black_box(fixed.forward(&pool[..ts]));
        });
    rec.put("model/q16_forward_ts100", st.median_ns);
    let st = Bench::new("rust q16: lockstep forward_batch B=8")
        .iters(rec.iters(20))
        .run(|| {
            std::hint::black_box(fixed.forward_batch(&pool[..8 * ts], 8));
        });
    rec.put("model/q16_forward_batch_b8_per_stream", st.median_ns / 8.0);

    // ---- quantized serving tier (register-blocked Q6.10 engine) ----
    // The serving-grade fixed-point engine behind MathPolicy::Quantized —
    // packed-once i16 panels, i64 gate accumulation, same lockstep shapes
    // as the f32 tiers. Two contracts are enforced BEFORE timing, exactly
    // like the FastSimd and par/* guards above: (a) the threaded engine is
    // bitwise the serial one (integer exactness makes this a hard
    // equality, not a tolerance), and (b) score drift vs BitExact stays
    // within model::fixed's stated accuracy bound.
    {
        let quant = FixedPackedAutoencoder::from_weights(&weights);
        let quant_par = FixedPackedAutoencoder::from_weights_threads(&weights, 4);
        let serial_scores = quant.score_batch(&pool[..8 * ts], 8);
        if quant_par.score_batch(&pool[..8 * ts], 8) != serial_scores {
            eprintln!(
                "FATAL: 4-thread quantized engine diverged from serial — \
                 fixed-point bit-exactness contract broken"
            );
            std::process::exit(1);
        }
        let exact_scores = packed.score_batch(&pool[..8 * ts], 8);
        let worst = exact_scores
            .iter()
            .zip(&serial_scores)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        if worst > QUANT_SCORE_TOL {
            eprintln!(
                "FATAL: quantized tier diverged from BitExact by {worst} \
                 (tolerance {QUANT_SCORE_TOL}) — math-tier contract broken"
            );
            std::process::exit(1);
        }
        println!(
            "Quantized vs BitExact score divergence: {worst:.2e} (tol {QUANT_SCORE_TOL:.0e}) — OK"
        );
        rec.put("quant/vs_bitexact_score_maxdiff", worst as f64);

        let mut q_b8_per_stream = f64::NAN;
        for &b in &[1usize, 8, 32] {
            let st = Bench::new(&format!("quant: blocked lockstep B={b} (q6.10)"))
                .iters(rec.iters(30))
                .run(|| {
                    std::hint::black_box(quant.forward_batch(&pool[..b * ts], b));
                });
            let per_stream = st.median_ns / b as f64;
            rec.put(&format!("quant/packed_b{b}_per_stream"), per_stream);
            println!(
                "  -> quant B={b}: {:.0} ns/stream ({:.0} streams/s)",
                per_stream,
                1e9 / per_stream
            );
            if b == 8 {
                q_b8_per_stream = per_stream;
            }
        }
        rec.put(
            "quant/vs_bitexact_b8_speedup",
            b8_per_stream / q_b8_per_stream,
        );
        let mut q_state = quant.zero_state(8);
        let st = Bench::new("quant: stateful continuation hop=25 B=8 (q6.10)")
            .iters(rec.iters(30))
            .run(|| {
                std::hint::black_box(quant.score_batch_stateful(
                    &pool[..8 * hop],
                    8,
                    &mut q_state,
                ));
            });
        rec.put("quant/stateful_hop25_b8_per_window", st.median_ns / 8.0);
        println!(
            "  -> quant vs bitexact @ B=8: {:.2}x per stream (software view of \
             the paper's fixed-point datapath)",
            b8_per_stream / q_b8_per_stream
        );
    }

    // ---- integer SIMD kernels vs their scalar references ----
    // The PR 9 tentpole measurements, both parity-guarded before timing.
    {
        // (a) i16 GEMM: the dispatched kernel (AVX2 madd when the CPU has
        // it, scalar otherwise — in which case the ratio reads ~1.0x) vs
        // the explicit scalar reference, on the nominal recurrent shape
        // (Lh=32 -> (32, 128)) at the serving batch. Bitwise guard first:
        // the kernels must agree exactly, not approximately.
        let (k, n, rows) = (32usize, 128usize, 8usize);
        let mut rng = Rng::new(0x51D);
        let w_q: Vec<i16> = (0..k * n)
            .map(|_| to_q16((rng.gaussian() * 0.4) as f32))
            .collect();
        let x_q: Vec<i16> = (0..rows * k)
            .map(|_| to_q16(rng.gaussian() as f32))
            .collect();
        let m = PackedMatrixI16::pack(&w_q, k, n);
        let mut z_simd = vec![0i64; rows * n];
        let mut z_scalar = vec![0i64; rows * n];
        m.gemm_acc_i64(&x_q, rows, &mut z_simd);
        m.gemm_acc_i64_scalar(&x_q, rows, &mut z_scalar);
        if z_simd != z_scalar {
            eprintln!(
                "FATAL: dispatched i16 GEMM diverged bitwise from the scalar \
                 reference — integer kernel contract broken"
            );
            std::process::exit(1);
        }
        let mut z = vec![0i64; rows * n];
        let st_simd = Bench::new("quant: i16 gemm, dispatched kernel")
            .iters(rec.iters(300))
            .run(|| {
                z.iter_mut().for_each(|v| *v = 0);
                m.gemm_acc_i64(&x_q, rows, &mut z);
                std::hint::black_box(&z);
            });
        let st_scalar = Bench::new("quant: i16 gemm, scalar reference")
            .iters(rec.iters(300))
            .run(|| {
                z.iter_mut().for_each(|v| *v = 0);
                m.gemm_acc_i64_scalar(&x_q, rows, &mut z);
                std::hint::black_box(&z);
            });
        rec.put(
            "quant/simd_vs_scalar_speedup",
            st_scalar.median_ns / st_simd.median_ns,
        );
        println!(
            "  -> i16 gemm dispatched vs scalar: {:.2}x",
            st_scalar.median_ns / st_simd.median_ns
        );

        // (b) gate tail: integer-domain LUT/PWL tail vs the frozen f32
        // round-trip tail. The two may differ only by activation-address
        // rounding (<= a few Q6.10 lsb on h) — guarded before timing.
        let lut = SigmoidLut::default();
        let lh = 32usize;
        let zrows: Vec<i64> = (0..rows * 4 * lh)
            .map(|_| (rng.gaussian() * 2.0 * (1u32 << 20) as f64) as i64)
            .collect();
        let c0: Vec<i32> = (0..rows * lh)
            .map(|i| ((i as i64 % 25 - 12) << 18) as i32)
            .collect();
        let mut c_int = c0.clone();
        let mut c_f32 = c0.clone();
        let mut h_int = vec![0i16; rows * lh];
        let mut h_f32 = vec![0i16; rows * lh];
        for r in 0..rows {
            fused_gate_tail(
                &lut,
                &zrows[r * 4 * lh..(r + 1) * 4 * lh],
                lh,
                &mut c_int[r * lh..(r + 1) * lh],
                &mut h_int[r * lh..(r + 1) * lh],
            );
            gate_tail_f32_reference(
                &lut,
                &zrows[r * 4 * lh..(r + 1) * 4 * lh],
                lh,
                &mut c_f32[r * lh..(r + 1) * lh],
                &mut h_f32[r * lh..(r + 1) * lh],
            );
        }
        let worst_h = h_int
            .iter()
            .zip(&h_f32)
            .map(|(&a, &b)| (a as i32 - b as i32).unsigned_abs())
            .max()
            .unwrap_or(0);
        if worst_h > 8 {
            eprintln!(
                "FATAL: integer gate tail diverged from the f32 reference by \
                 {worst_h} Q6.10 lsb — address-rounding contract broken"
            );
            std::process::exit(1);
        }
        let mut c_bench = c0.clone();
        let mut h_bench = vec![0i16; rows * lh];
        let st_int = Bench::new("quant: gate tail, integer domain")
            .iters(rec.iters(300))
            .run(|| {
                c_bench.copy_from_slice(&c0);
                for r in 0..rows {
                    fused_gate_tail(
                        &lut,
                        &zrows[r * 4 * lh..(r + 1) * 4 * lh],
                        lh,
                        &mut c_bench[r * lh..(r + 1) * lh],
                        &mut h_bench[r * lh..(r + 1) * lh],
                    );
                }
                std::hint::black_box(&h_bench);
            });
        let st_f32 = Bench::new("quant: gate tail, f32 round-trip reference")
            .iters(rec.iters(300))
            .run(|| {
                c_bench.copy_from_slice(&c0);
                for r in 0..rows {
                    gate_tail_f32_reference(
                        &lut,
                        &zrows[r * 4 * lh..(r + 1) * 4 * lh],
                        lh,
                        &mut c_bench[r * lh..(r + 1) * lh],
                        &mut h_bench[r * lh..(r + 1) * lh],
                    );
                }
                std::hint::black_box(&h_bench);
            });
        rec.put(
            "quant/gate_tail_int_vs_f32_speedup",
            st_f32.median_ns / st_int.median_ns,
        );
        println!(
            "  -> gate tail integer vs f32 round-trip: {:.2}x",
            st_f32.median_ns / st_int.median_ns
        );
    }

    // ---- PJRT datapath (artifacts required) ----
    'pjrt: {
        let Ok(manifest) = Manifest::load("artifacts") else {
            eprintln!("artifacts/ missing — PJRT datapath benches skipped");
            break 'pjrt;
        };
        let Ok(engine) = Engine::cpu() else {
            eprintln!("PJRT client unavailable — PJRT benches skipped");
            break 'pjrt;
        };
        let (Ok(small), Ok(nominal)) = (
            engine.load_variant(&manifest, "small_ts8"),
            engine.load_variant(&manifest, "nominal_ts100"),
        ) else {
            eprintln!("PJRT compile unavailable (offline xla shim) — PJRT benches skipped");
            break 'pjrt;
        };

        let mut s8 = StrainStream::new(2, 8, DEFAULT_SNR, 0.0);
        let w8 = s8.next_window();
        let mut s100 = StrainStream::new(3, 100, DEFAULT_SNR, 0.0);
        let w100 = s100.next_window();

        let st = Bench::new("PJRT: small_ts8 batch-1 infer")
            .warmup(10)
            .iters(rec.iters(200))
            .run(|| {
                std::hint::black_box(small.infer(&w8.samples).unwrap());
            });
        rec.put("pjrt/small_ts8_infer", st.median_ns);
        let st = Bench::new("PJRT: nominal_ts100 batch-1 infer")
            .warmup(10)
            .iters(rec.iters(100))
            .run(|| {
                std::hint::black_box(nominal.infer(&w100.samples).unwrap());
            });
        rec.put("pjrt/nominal_ts100_infer", st.median_ns);
    }

    let st = Bench::new("rust f32: nominal_ts100 forward (scalar)")
        .iters(rec.iters(100))
        .run(|| {
            std::hint::black_box(forward_f32(&weights, &pool[..ts]));
        });
    rec.put("model/f32_forward_ts100", st.median_ns);

    rec.flush("BENCH_hotpath.json");
    base.flush("BENCH_hotpath_pr1_baseline.json");
}
