//! Bench target: hot-path microbenchmarks — the §Perf iteration harness.
//!
//! Covers every layer the perf pass optimizes:
//!   L3 rust: PJRT inference (small + nominal), pure-rust f32 forward,
//!            fixed-point forward, cycle-simulator throughput, DSE speed,
//!            window generation (FFT + filters), router dispatch.
//!
//! Run: `make artifacts && cargo bench --bench hotpath`

use gwlstm::config::Manifest;
use gwlstm::coordinator::router::{Job, Router};
use gwlstm::gw::dataset::{StrainStream, DEFAULT_SNR};
use gwlstm::gw::fft::Plan;
use gwlstm::gw::psd::colored_noise;
use gwlstm::hls::device::Device;
use gwlstm::hls::dse::partition_model;
use gwlstm::hls::perf_model::{DesignPoint, LayerDims};
use gwlstm::model::{forward_f32, AutoencoderWeights, FixedAutoencoder};
use gwlstm::runtime::Engine;
use gwlstm::sim::{simulate, SimConfig};
use gwlstm::util::bench::Bench;
use gwlstm::util::rng::Rng;

fn main() {
    // ---- simulator & DSE (no artifacts needed) ----
    let u250 = Device::by_name("u250").unwrap();
    let point = DesignPoint::nominal_autoencoder(9, 1, 8);
    let st = Bench::new("cycle-sim: nominal x128 inferences")
        .iters(50)
        .run(|| {
            let r = simulate(&SimConfig {
                point: point.clone(),
                device: *u250,
                inferences: 128,
                arrival_interval: None,
                rewind: true,
                overlap: true,
            });
            std::hint::black_box(r.makespan);
        });
    // simulated-cycles per wall-second (the §Perf L3 target metric)
    let sim_cycles = {
        let r = simulate(&SimConfig {
            point: point.clone(),
            device: *u250,
            inferences: 128,
            arrival_interval: None,
            rewind: true,
            overlap: true,
        });
        r.makespan as f64
    };
    println!(
        "  -> simulator speed: {:.1} M simulated cycles / s",
        sim_cycles / (st.median_ns / 1e9) / 1e6
    );

    let layers = vec![
        LayerDims::new(1, 32),
        LayerDims::new(32, 8),
        LayerDims::new(8, 8),
        LayerDims::new(8, 32),
    ];
    Bench::new("DSE: partition nominal @ 2800 DSPs")
        .iters(200)
        .run(|| {
            let p = partition_model(u250, &layers, 8, 1, 2_800);
            std::hint::black_box(p.perf.dsp_model);
        });

    // ---- GW substrate ----
    let plan = Plan::new(2048);
    let mut rng = Rng::new(0);
    Bench::new("gw: colored_noise 2048 samples").iters(100).run(|| {
        std::hint::black_box(colored_noise(&mut rng, &plan, 2048.0));
    });
    let mut stream = StrainStream::new(1, 100, DEFAULT_SNR, 0.3);
    Bench::new("gw: StrainStream next_window (TS=100)")
        .iters(100)
        .run(|| {
            std::hint::black_box(stream.next_window());
        });

    // ---- router dispatch (queue cost only) ----
    Bench::new("router: dispatch+drain 1024 jobs x4 workers")
        .iters(50)
        .run(|| {
            let (router, queues) = Router::new(4, 512);
            for seq in 0..1024u64 {
                let _ = router.route(Job { seq, payload: seq });
            }
            router.shutdown();
            let mut got = 0;
            for q in &queues {
                while q.recv().is_some() {
                    got += 1;
                }
            }
            std::hint::black_box(got);
        });

    // ---- model datapaths (artifacts required) ----
    let Ok(manifest) = Manifest::load("artifacts") else {
        eprintln!("artifacts/ missing — model datapath benches skipped");
        return;
    };
    let engine = Engine::cpu().expect("PJRT");
    let small = engine.load_variant(&manifest, "small_ts8").expect("small");
    let nominal = engine
        .load_variant(&manifest, "nominal_ts100")
        .expect("nominal");
    let weights = AutoencoderWeights::load("artifacts/weights_nominal.json").expect("weights");
    let fixed = FixedAutoencoder::from_weights(&weights);

    let mut s8 = StrainStream::new(2, 8, DEFAULT_SNR, 0.0);
    let w8 = s8.next_window();
    let mut s100 = StrainStream::new(3, 100, DEFAULT_SNR, 0.0);
    let w100 = s100.next_window();

    Bench::new("PJRT: small_ts8 batch-1 infer").warmup(10).iters(200).run(|| {
        std::hint::black_box(small.infer(&w8.samples).unwrap());
    });
    Bench::new("PJRT: nominal_ts100 batch-1 infer")
        .warmup(10)
        .iters(100)
        .run(|| {
            std::hint::black_box(nominal.infer(&w100.samples).unwrap());
        });
    Bench::new("rust f32: nominal_ts100 forward").iters(100).run(|| {
        std::hint::black_box(forward_f32(&weights, &w100.samples));
    });
    Bench::new("rust q16: nominal_ts100 forward").iters(100).run(|| {
        std::hint::black_box(fixed.forward(&w100.samples));
    });
}
