//! Bench target: regenerate **Table II** (the six FPGA design points) and
//! time the evaluation machinery (analytical model + 32-inference cycle
//! simulation per design).
//!
//! Run: `cargo bench --bench table2_designs`

use gwlstm::report::{evaluate_design, render_table2, table2_designs};
use gwlstm::util::bench::Bench;

fn main() {
    println!("=== Table II: FPGA design points (paper vs model vs simulator) ===\n");
    render_table2().print();

    println!("\n--- headline checks ---");
    let designs = table2_designs();
    let z1 = evaluate_design(&designs[0]);
    let z3 = evaluate_design(&designs[2]);
    println!(
        "Z1 -> Z3: same II ({} == {}), DSPs {} -> {} ({:.0}% saved), fits Zynq: {} -> {}",
        z1.perf.ii_sys,
        z3.perf.ii_sys,
        z1.perf.dsp_model,
        z3.perf.dsp_model,
        100.0 * (1.0 - z3.perf.dsp_model as f64 / z1.perf.dsp_model as f64),
        z1.perf.dsp_model <= 900,
        z3.perf.dsp_model <= 900,
    );
    let u1 = evaluate_design(&designs[3]);
    let u2 = evaluate_design(&designs[4]);
    let u3 = evaluate_design(&designs[5]);
    println!(
        "U1 -> U2: same II, {} DSPs saved (paper: 2102)",
        u1.perf.dsp_model - u2.perf.dsp_model
    );
    println!(
        "U3 vs U2/U1: {:.1}x / {:.1}x fewer DSPs (paper: 3.3x / 4.1x)",
        u2.perf.dsp_model as f64 / u3.perf.dsp_model as f64,
        u1.perf.dsp_model as f64 / u3.perf.dsp_model as f64
    );

    println!("\n--- timing ---");
    for d in &designs {
        Bench::new(&format!("evaluate {}", d.label))
            .warmup(2)
            .iters(20)
            .run(|| {
                let _ = evaluate_design(d);
            });
    }
}
