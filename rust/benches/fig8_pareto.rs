//! Bench target: regenerate **Fig. 8** — the (DSP, II) Pareto frontier of a
//! single LSTM layer (Lx = Lh = 32), naive `R_x = R_h` family vs the
//! balanced family of Eq. 7. Emits the series as CSV for plotting.
//!
//! Also emits the *software* speed-vs-accuracy Pareto: the three serving
//! math tiers (BitExact, FastSimd, Quantized Q6.10) measured on the same
//! windows — the software mirror of the paper's hardware trade-off, where
//! the fixed-point datapath buys throughput at a bounded accuracy cost
//! (Section V-B: "negligible effect" — quantified here as worst per-window
//! score drift vs BitExact).
//!
//! Run: `cargo bench --bench fig8_pareto`

use gwlstm::gw::dataset::{StrainStream, DEFAULT_SNR};
use gwlstm::hls::pareto::{frontier, max_saving_same_ii};
use gwlstm::model::{AutoencoderWeights, FixedPackedAutoencoder, MathPolicy, PackedAutoencoder};
use gwlstm::report::{fig8_series, render_fig8};
use gwlstm::util::bench::Bench;

/// One software-tier Pareto point: median ns/stream at B=8 plus worst
/// per-window score drift vs the BitExact reference on the same windows.
fn tier_point(name: &str, score: impl Fn(&[f32]) -> Vec<f32>, pool: &[f32], reference: &[f32], iters: usize) -> (String, f64, f64) {
    let scores = score(pool);
    let maxdiff = scores
        .iter()
        .zip(reference)
        .map(|(a, b)| (a - b).abs() as f64)
        .fold(0.0f64, f64::max);
    let st = Bench::new(&format!("tier {name}: score_batch B=8")).iters(iters).run(|| {
        std::hint::black_box(score(pool));
    });
    (name.to_string(), st.median_ns / 8.0, maxdiff)
}

fn main() {
    println!("=== Fig. 8: Pareto frontier, naive vs balanced II ===\n");
    render_fig8().print();

    let (naive, balanced) = fig8_series();
    println!("\n--- CSV (family,rh,rx,dsp,ii) ---");
    for p in &naive {
        println!("naive,{},{},{},{}", p.rh, p.rx, p.dsp, p.ii);
    }
    for p in &balanced {
        println!("balanced,{},{},{},{}", p.rh, p.rx, p.dsp, p.ii);
    }

    let mut all = naive.clone();
    all.extend(balanced.iter().cloned());
    let front = frontier(&all);
    let balanced_on_front = front.iter().filter(|p| p.rx != p.rh).count();
    println!(
        "\nfrontier: {} points, {} from the balanced family — balancing moves\n\
         the frontier (paper: red line -> blue line); max same-II DSP saving {:.0}%",
        front.len(),
        balanced_on_front,
        100.0 * max_saving_same_ii(&naive, &balanced)
    );
    // A -> C and A -> B anchors from the paper's narrative
    let a = &naive[0];
    let c = &balanced[0];
    println!(
        "A(naive r=1: {} DSP, II {}) -> C(balanced rh=1: {} DSP, II {}): same II, {:.0}% fewer DSPs",
        a.dsp,
        a.ii,
        c.dsp,
        c.ii,
        100.0 * (1.0 - c.dsp as f64 / a.dsp as f64)
    );

    // ---- software math-tier Pareto (speed vs accuracy) ----
    let ts = 100usize;
    let batch = 8usize;
    let weights = AutoencoderWeights::synthetic(0xBA7C, "nominal");
    let exact = PackedAutoencoder::from_weights(&weights);
    let fast = PackedAutoencoder::from_weights_policy(&weights, MathPolicy::FastSimd);
    let quant = FixedPackedAutoencoder::from_weights(&weights);
    let mut stream = StrainStream::new(9, ts, DEFAULT_SNR, 0.3);
    let mut pool: Vec<f32> = Vec::with_capacity(batch * ts);
    for _ in 0..batch {
        pool.extend_from_slice(&stream.next_window().samples);
    }
    let reference = exact.score_batch(&pool, batch);
    let smoke = std::env::var("GWLSTM_BENCH_SMOKE").is_ok();
    let iters = if smoke { 2 } else { 30 };
    let points = [
        tier_point("bitexact", |w| exact.score_batch(w, batch), &pool, &reference, iters),
        tier_point("fast_simd", |w| fast.score_batch(w, batch), &pool, &reference, iters),
        tier_point("quantized", |w| quant.score_batch(w, batch), &pool, &reference, iters),
    ];
    println!("\n=== software math-tier Pareto: speed vs accuracy (B=8, TS=100) ===");
    println!("\n--- CSV (tier,ns_per_stream,score_maxdiff_vs_bitexact) ---");
    for (name, ns, maxdiff) in &points {
        println!("{name},{ns:.0},{maxdiff:.3e}");
    }
    println!(
        "\nquantized is the software view of the paper's FPGA datapath: the\n\
         accuracy axis is bounded by model::fixed::QUANT_SCORE_TOL (asserted\n\
         in tests/fixed_parity.rs), the speed axis is what the Q6.10 integer\n\
         engine buys on this host."
    );

    println!("\n--- timing ---");
    Bench::new("full fig8 sweep (20 design points)").iters(100).run(|| {
        let _ = fig8_series();
    });
}
