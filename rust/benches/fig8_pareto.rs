//! Bench target: regenerate **Fig. 8** — the (DSP, II) Pareto frontier of a
//! single LSTM layer (Lx = Lh = 32), naive `R_x = R_h` family vs the
//! balanced family of Eq. 7. Emits the series as CSV for plotting.
//!
//! Run: `cargo bench --bench fig8_pareto`

use gwlstm::hls::pareto::{frontier, max_saving_same_ii};
use gwlstm::report::{fig8_series, render_fig8};
use gwlstm::util::bench::Bench;

fn main() {
    println!("=== Fig. 8: Pareto frontier, naive vs balanced II ===\n");
    render_fig8().print();

    let (naive, balanced) = fig8_series();
    println!("\n--- CSV (family,rh,rx,dsp,ii) ---");
    for p in &naive {
        println!("naive,{},{},{},{}", p.rh, p.rx, p.dsp, p.ii);
    }
    for p in &balanced {
        println!("balanced,{},{},{},{}", p.rh, p.rx, p.dsp, p.ii);
    }

    let mut all = naive.clone();
    all.extend(balanced.iter().cloned());
    let front = frontier(&all);
    let balanced_on_front = front.iter().filter(|p| p.rx != p.rh).count();
    println!(
        "\nfrontier: {} points, {} from the balanced family — balancing moves\n\
         the frontier (paper: red line -> blue line); max same-II DSP saving {:.0}%",
        front.len(),
        balanced_on_front,
        100.0 * max_saving_same_ii(&naive, &balanced)
    );
    // A -> C and A -> B anchors from the paper's narrative
    let a = &naive[0];
    let c = &balanced[0];
    println!(
        "A(naive r=1: {} DSP, II {}) -> C(balanced rh=1: {} DSP, II {}): same II, {:.0}% fewer DSPs",
        a.dsp,
        a.ii,
        c.dsp,
        c.ii,
        100.0 * (1.0 - c.dsp as f64 / a.dsp as f64)
    );

    println!("\n--- timing ---");
    Bench::new("full fig8 sweep (20 design points)").iters(100).run(|| {
        let _ = fig8_series();
    });
}
