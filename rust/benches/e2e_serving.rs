//! Bench target: end-to-end serving — the full coordinator pipeline on the
//! live synthetic stream, batch-1 (the paper's mode) vs micro-batching
//! (the related-work mode whose latency penalty the paper calls out) vs
//! the streaming state service (resident per-stream state, one lockstep
//! stateful call per tick — the continuous-inference workload).
//!
//! Two backends:
//! * **native batched** (always runs, no artifacts): micro-batches execute
//!   as single lockstep engine calls, so the sweep shows the real
//!   latency/throughput trade-off of batching the batched engine;
//! * **PJRT artifacts** (requires `make artifacts`): the paper's AOT path.
//!
//! Run: `cargo bench --bench e2e_serving`. Set `GWLSTM_BENCH_SMOKE=1` for
//! the ci.sh smoke invocation (tiny window counts), `GWLSTM_MATH=
//! bitexact|fast_simd` to pick the native engine's math tier (ci.sh runs
//! the smoke in both), and `GWLSTM_THREADS=N` to give every native engine
//! (stateless policies AND the streaming/ingress arms) an N-lane balanced-
//! partition worker pool — the thread-sweep arm of the serving tables
//! without a new bench binary. Scores are bit-identical across N; only the
//! latency/throughput columns move. The PJRT sweep ignores threads by
//! design (`run_serving_with_policy` would reject it) and always serves
//! with the default single-threaded config.
//!
//! The sharded-tier arms sweep `GWLSTM_SHARDS` (default `1,2,4`) shard
//! lanes over a `GWLSTM_SHARD_SESSIONS` (default 100 000) resident-session
//! population — one full pass so every session is resident — and emit
//! `shard/<n>/...` scaling keys per math tier.
//!
//! Emits `BENCH_serving.json` with the ingress pipeline's headline keys
//! (`ingress/<arrival>/e2e_p99_us/<tier>` etc.), merged with any existing
//! file contents so ci.sh's two tier passes accumulate instead of
//! clobbering each other.

use std::collections::BTreeMap;
use std::time::Duration;

use gwlstm::config::{Manifest, ServeConfig};
use gwlstm::coordinator::{
    run_serving_native, run_serving_streaming, run_serving_with_policy, Arrival, FaultSpec,
    Policy, ServeReport,
};
use gwlstm::model::{AutoencoderWeights, MathPolicy};
use gwlstm::util::bench::Table;
use gwlstm::util::json::Value;

fn policies() -> Vec<(&'static str, Policy)> {
    vec![
        ("batch-1 (paper)", Policy::Immediate),
        (
            "micro-batch 4 / 1ms",
            Policy::MicroBatch {
                max_batch: 4,
                max_wait: Duration::from_millis(1),
            },
        ),
        (
            "micro-batch 16 / 5ms",
            Policy::MicroBatch {
                max_batch: 16,
                max_wait: Duration::from_millis(5),
            },
        ),
    ]
}

/// Merge-on-write JSON emission: ci.sh runs the smoke once per math tier,
/// so each pass must keep the other tier's keys instead of clobbering the
/// file (the hotpath bench's Recorder convention, plus the merge).
fn flush_bench_keys(path: &str, keys: BTreeMap<String, Value>) {
    let mut out: BTreeMap<String, Value> = match Value::from_file(path) {
        Ok(v) => v.as_obj().map(Clone::clone).unwrap_or_default(),
        Err(_) => BTreeMap::new(),
    };
    let n = keys.len();
    out.extend(keys);
    match std::fs::write(path, Value::Obj(out).to_string()) {
        Ok(()) => println!("\nwrote {n} ingress keys to {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}

fn table_for(rows: Vec<(&str, ServeReport)>) -> Table {
    let mut t = Table::new(&[
        "policy",
        "windows",
        "batches",
        "mean B",
        "AUC",
        "infer p50 (us)",
        "e2e p50 (us)",
        "e2e p99 (us)",
        "throughput (win/s)",
    ]);
    for (name, r) in rows {
        t.row(&[
            name.into(),
            r.windows.to_string(),
            r.batches.to_string(),
            format!("{:.2}", r.mean_batch),
            format!("{:.3}", r.auc),
            format!("{:.1}", r.infer.p50_ns / 1e3),
            format!("{:.1}", r.e2e.p50_ns / 1e3),
            format!("{:.1}", r.e2e.p99_ns / 1e3),
            format!("{:.0}", r.throughput_per_s),
        ]);
    }
    t
}

fn main() {
    let smoke = std::env::var("GWLSTM_BENCH_SMOKE").is_ok();
    let windows = if smoke { 120 } else { 600 };
    let math = match std::env::var("GWLSTM_MATH") {
        Ok(s) => MathPolicy::parse(&s).expect("GWLSTM_MATH"),
        Err(_) => MathPolicy::BitExact,
    };
    let threads = gwlstm::model::par::threads_from_env(1);

    // ---- native batched backend (always available) ----
    let weights = AutoencoderWeights::synthetic(0x5E4E, "small");
    let cfg = ServeConfig {
        model: "small_native".into(),
        calib_windows: if smoke { 32 } else { 64 },
        max_windows: windows,
        inject_prob: 0.25,
        math_policy: math,
        threads,
        ..Default::default()
    };
    let mut rows = Vec::new();
    for (name, policy) in policies() {
        let r = run_serving_native(&weights, 8, &cfg, policy).expect("native serving run");
        rows.push((name, r));
    }
    // Streaming state service arm: S resident sessions advanced one hop of
    // NEW samples per tick (stateful continuation) — the continuous-
    // inference workload the stateless policies above cannot express. One
    // lockstep stateful call per tick, so mean B ≈ S with no batching
    // queue delay; ci.sh runs this smoke in both math tiers (GWLSTM_MATH).
    let scfg = ServeConfig {
        model: "small_stream".into(),
        calib_windows: if smoke { 16 } else { 48 },
        max_windows: windows,
        inject_prob: 0.25,
        math_policy: math,
        threads,
        streaming: true,
        stream_sessions: 8,
        stream_hop: 8,
        ..Default::default()
    };
    let r = run_serving_streaming(&weights, &scfg).expect("streaming serving run");
    rows.push(("streaming stateful S=8 hop=8", r));
    // Ingress arms: the async front door in front of the same streaming
    // service — bounded-MPSC producers, double-buffered ticks (ingest and
    // gather tick N+1 while the engine computes tick N). Uniform arrivals
    // measure the pipelining win directly against the serial streaming row
    // above; bursty arrivals (1-8-chunk bursts at the same mean rate) are
    // the arm the p99 tail keys are judged on.
    let mut bench_keys: BTreeMap<String, Value> = BTreeMap::new();
    for arrival in [Arrival::Uniform, Arrival::Bursty] {
        let icfg = ServeConfig {
            model: "small_ingress".into(),
            arrival,
            ingress: true,
            // pace the feeds so arrival shape (not producer saturation)
            // dominates the tail; bursts then genuinely queue
            pace_us: 50,
            slo_us: 0, // shedding off: bit-exact vs the serial loop
            ..scfg.clone()
        };
        let r = run_serving_streaming(&weights, &icfg).expect("ingress serving run");
        assert_eq!(
            r.ingested,
            r.windows as u64 + r.dropped + r.quarantined,
            "ingress conservation violated in bench"
        );
        let prefix = format!("ingress/{}", arrival.label());
        let tier = math.label();
        bench_keys.insert(
            format!("{prefix}/e2e_p50_us/{tier}"),
            Value::Num(r.e2e.p50_ns / 1e3),
        );
        bench_keys.insert(
            format!("{prefix}/e2e_p99_us/{tier}"),
            Value::Num(r.e2e.p99_ns / 1e3),
        );
        bench_keys.insert(
            format!("{prefix}/infer_p50_us/{tier}"),
            Value::Num(r.infer.p50_ns / 1e3),
        );
        bench_keys.insert(
            format!("{prefix}/throughput_win_per_s/{tier}"),
            Value::Num(r.throughput_per_s),
        );
        bench_keys.insert(
            format!("{prefix}/dropped/{tier}"),
            Value::Num(r.dropped as f64),
        );
        let label: &'static str = match arrival {
            Arrival::Uniform => "ingress pipelined S=8 hop=8 uniform",
            Arrival::Bursty => "ingress pipelined S=8 hop=8 bursty",
        };
        rows.push((label, r));
    }
    // Fault arms: seeded chaos campaigns through the same ingress pipeline
    // (coordinator::chaos) — what the fault-tolerance layer COSTS and how
    // much it catches, per tier. `GWLSTM_FAULTS=<spec>` adds a custom arm.
    let mut fault_arms: Vec<(String, String)> = vec![
        ("nan_burst".into(), "seed=11,nan=0.05".into()),
        ("stall".into(), "seed=12,stall=0.05,stall_us=200".into()),
        ("panic".into(), "seed=13,panic@3,panic@7,panic@20".into()),
    ];
    if let Ok(s) = std::env::var("GWLSTM_FAULTS") {
        if !s.trim().is_empty() {
            fault_arms.push(("custom".into(), s));
        }
    }
    println!("\n=== chaos campaigns (ingress + seeded faults, {} tier) ===", math.label());
    for (arm, spec) in &fault_arms {
        let fcfg = ServeConfig {
            model: format!("small_faults_{arm}"),
            arrival: Arrival::Uniform,
            ingress: true,
            pace_us: 50,
            slo_us: 0,
            faults: Some(FaultSpec::parse(spec).expect("bench fault spec")),
            ..scfg.clone()
        };
        let r = run_serving_streaming(&weights, &fcfg).expect("chaos serving run");
        assert_eq!(
            r.ingested,
            r.windows as u64 + r.dropped + r.quarantined,
            "chaos arm {arm}: conservation violated"
        );
        println!(
            "  {arm:<10} served {} quarantined {} recovered {} panics {} e2e p99 {:.1} us",
            r.windows, r.quarantined, r.recovered, r.engine_panics, r.e2e.p99_ns / 1e3
        );
        let tier = math.label();
        bench_keys.insert(
            format!("faults/{arm}/quarantined/{tier}"),
            Value::Num(r.quarantined as f64),
        );
        bench_keys.insert(
            format!("faults/{arm}/recovered/{tier}"),
            Value::Num(r.recovered as f64),
        );
        bench_keys.insert(
            format!("faults/{arm}/e2e_p99_us/{tier}"),
            Value::Num(r.e2e.p99_ns / 1e3),
        );
    }
    // Shard arms: the sharded serving tier at shards ∈ GWLSTM_SHARDS
    // (default "1,2,4") over a 100k-resident-session population
    // (GWLSTM_SHARD_SESSIONS overrides) — the registry-scale workload one
    // lane's lockstep batch cannot hold comfortably. max_windows == the
    // population, so one full pass makes every session resident; the
    // `shards=1` row is the unsharded baseline on the identical workload.
    let shard_counts: Vec<usize> = match std::env::var("GWLSTM_SHARDS") {
        Ok(s) if !s.trim().is_empty() => s
            .split(',')
            .map(|t| t.trim().parse().expect("GWLSTM_SHARDS: comma-separated shard counts"))
            .collect(),
        _ => vec![1, 2, 4],
    };
    let shard_sessions: usize = std::env::var("GWLSTM_SHARD_SESSIONS")
        .ok()
        .and_then(|s| s.trim().parse().ok())
        .unwrap_or(100_000);
    println!(
        "\n=== sharded serving tier ({} resident sessions, {} tier) ===",
        shard_sessions,
        math.label()
    );
    for &shards in &shard_counts {
        let shcfg = ServeConfig {
            model: format!("small_shard{shards}"),
            calib_windows: 16,
            max_windows: shard_sessions,
            stream_sessions: shard_sessions,
            arrival: Arrival::Uniform,
            ingress: true,
            shards,
            pace_us: 0,
            slo_us: 0,
            queue_depth: 256,
            ..scfg.clone()
        };
        let r = run_serving_streaming(&weights, &shcfg).expect("sharded serving run");
        assert_eq!(
            r.ingested,
            r.windows as u64 + r.dropped + r.quarantined,
            "shards={shards}: conservation violated in bench"
        );
        for l in &r.shard_ledgers {
            assert!(l.conserved(), "shards={shards}: shard {} ledger leaked", l.shard);
        }
        println!(
            "  shards={shards:<2} served {} mean B {:.0} dropped {} e2e p99 {:.1} us \
             throughput {:.0} win/s",
            r.windows, r.mean_batch, r.dropped, r.e2e.p99_ns / 1e3, r.throughput_per_s
        );
        let tier = math.label();
        bench_keys.insert(
            format!("shard/{shards}/throughput_win_per_s/{tier}"),
            Value::Num(r.throughput_per_s),
        );
        bench_keys.insert(
            format!("shard/{shards}/e2e_p99_us/{tier}"),
            Value::Num(r.e2e.p99_ns / 1e3),
        );
        bench_keys.insert(
            format!("shard/{shards}/resident_sessions/{tier}"),
            Value::Num(shard_sessions as f64),
        );
        bench_keys.insert(
            format!("shard/{shards}/dropped/{tier}"),
            Value::Num(r.dropped as f64),
        );
    }
    bench_keys.insert(
        "_meta".to_string(),
        Value::Str(
            "ingress + faults + shard serving keys from benches/e2e_serving.rs; \
             tiers merge across ci.sh passes (see BENCHMARKS.md)"
                .to_string(),
        ),
    );
    flush_bench_keys("BENCH_serving.json", bench_keys);
    println!(
        "=== e2e serving (native batched engine, {} tier, {threads} engine thread(s)): policy trade-off ===\n",
        math.label()
    );
    table_for(rows).print();
    println!(
        "\nstreaming row: resident per-stream (h, c) — each window scores only\n\
         hop new samples against carried state instead of re-encoding a full\n\
         window from zeros (see BENCH_hotpath.json stream/* for the per-window\n\
         engine-cost comparison)."
    );

    // ---- PJRT artifact backend ----
    let Ok(manifest) = Manifest::load("artifacts") else {
        eprintln!("\nartifacts/ missing — PJRT e2e sweep skipped (run `make artifacts`)");
        return;
    };
    let cfg = ServeConfig {
        model: "small_ts8".into(),
        calib_windows: if smoke { 32 } else { 64 },
        max_windows: windows,
        inject_prob: 0.25,
        ..Default::default()
    };
    let mut rows = Vec::new();
    for (name, policy) in policies() {
        match run_serving_with_policy(&manifest, &cfg, policy) {
            Ok(r) => rows.push((name, r)),
            Err(e) => {
                eprintln!("\nPJRT serving unavailable ({e}) — PJRT e2e sweep skipped");
                return;
            }
        }
    }
    println!("\n=== e2e serving (PJRT artifacts): policy trade-off ===\n");
    table_for(rows).print();
    println!(
        "\npaper (Section V-C / VI): batch-1 because 'a newly arrived request\n\
         has to wait until the batch is formed, which imposes a significant\n\
         latency penalty' — visible above as the e2e p50/p99 gap."
    );
}
