//! Bench target: end-to-end serving — the full coordinator pipeline on the
//! live synthetic stream, batch-1 (the paper's mode) vs micro-batching
//! (the related-work mode whose latency penalty the paper calls out).
//!
//! Run: `make artifacts && cargo bench --bench e2e_serving`

use std::time::Duration;

use gwlstm::config::{Manifest, ServeConfig};
use gwlstm::coordinator::{run_serving_with_policy, Policy};
use gwlstm::util::bench::Table;

fn main() {
    let Ok(manifest) = Manifest::load("artifacts") else {
        eprintln!("artifacts/ missing — run `make artifacts` first");
        return;
    };
    let cfg = ServeConfig {
        model: "small_ts8".into(),
        calib_windows: 64,
        max_windows: 600,
        inject_prob: 0.25,
        ..Default::default()
    };

    let policies: Vec<(&str, Policy)> = vec![
        ("batch-1 (paper)", Policy::Immediate),
        (
            "micro-batch 4 / 1ms",
            Policy::MicroBatch {
                max_batch: 4,
                max_wait: Duration::from_millis(1),
            },
        ),
        (
            "micro-batch 16 / 5ms",
            Policy::MicroBatch {
                max_batch: 16,
                max_wait: Duration::from_millis(5),
            },
        ),
    ];

    let mut t = Table::new(&[
        "policy",
        "windows",
        "AUC",
        "infer p50 (us)",
        "e2e p50 (us)",
        "e2e p99 (us)",
        "throughput (win/s)",
    ]);
    for (name, policy) in policies {
        let r = run_serving_with_policy(&manifest, &cfg, policy).expect("serving run");
        t.row(&[
            name.into(),
            r.windows.to_string(),
            format!("{:.3}", r.auc),
            format!("{:.1}", r.infer.p50_ns / 1e3),
            format!("{:.1}", r.e2e.p50_ns / 1e3),
            format!("{:.1}", r.e2e.p99_ns / 1e3),
            format!("{:.0}", r.throughput_per_s),
        ]);
    }
    println!("=== e2e serving: batching policy latency/throughput trade-off ===\n");
    t.print();
    println!(
        "\npaper (Section V-C / VI): batch-1 because 'a newly arrived request\n\
         has to wait until the batch is formed, which imposes a significant\n\
         latency penalty' — visible above as the e2e p50/p99 gap."
    );
}
