//! Bench target: regenerate **Table III** — batch-1 latency of the nominal
//! autoencoder on CPU (measured through the PJRT runtime), GPU (modeled,
//! DESIGN.md §2) and FPGA (cycle simulator).
//!
//! Run: `make artifacts && cargo bench --bench table3_platforms`

use gwlstm::config::Manifest;
use gwlstm::gw::dataset::{StrainStream, DEFAULT_SNR};
use gwlstm::report::render_table3;
use gwlstm::runtime::Engine;
use gwlstm::util::bench::{fmt_ns, Bench};

fn main() {
    // measured CPU latency via the PJRT runtime (the paper's CPU role)
    let measured = match Manifest::load("artifacts") {
        Ok(manifest) => {
            let engine = Engine::cpu().expect("PJRT client");
            let exe = engine
                .load_variant(&manifest, "nominal_ts100")
                .expect("artifact");
            let mut stream = StrainStream::new(3, exe.spec.ts, DEFAULT_SNR, 0.0);
            let w = stream.next_window();
            let stats = Bench::new("CPU (PJRT/XLA) nominal_ts100 batch-1")
                .warmup(5)
                .iters(60)
                .run(|| {
                    exe.infer(&w.samples).unwrap();
                });
            println!(
                "  -> CPU measured median {} (p99 {})",
                fmt_ns(stats.median_ns),
                fmt_ns(stats.p99_ns)
            );
            Some(stats.median_ns / 1e3)
        }
        Err(_) => {
            eprintln!("artifacts/ missing — run `make artifacts` for the measured CPU row");
            None
        }
    };

    println!("\n=== Table III: latency across platforms ===\n");
    render_table3(measured).print();
    println!(
        "\nNote: the paper's CPU (E2620, 39.7 ms) ran TS=100 windows through\n\
         an unbatched keras/TF stack; our XLA-CPU path is faster in absolute\n\
         terms, but the *shape* — FPGA is 4-5 orders of magnitude below both\n\
         general-purpose platforms at batch 1 — is reproduced."
    );
}
