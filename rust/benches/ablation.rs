//! Bench target: **ablation** of the paper's architectural mechanisms, each
//! switched off independently on the Table II designs:
//!
//! * timestep overlap between cascaded layers (Fig. 7) — off reverts to the
//!   Fig. 1 naive schedule where a layer waits for its producer's full
//!   sequence;
//! * loop rewind (Eq. 1) — off pays the `LT_N - ii_N` pipeline drain per
//!   inference per layer;
//! * balanced II (Eq. 7) — "unbalanced" gives layer 0 heavy reuse and layer
//!   1 full unroll at the *same total DSP budget shape* (the Fig. 1 story);
//! * micro-batching vs batch-1 is covered by `e2e_serving`.
//!
//! Run: `cargo bench --bench ablation`

use gwlstm::hls::device::Device;
use gwlstm::hls::perf_model::{model_perf, DesignPoint, LayerDims};
use gwlstm::sim::{simulate, SimConfig};
use gwlstm::util::bench::Table;

fn run(point: &DesignPoint, dev: &Device, rewind: bool, overlap: bool) -> (u64, f64) {
    let r = simulate(&SimConfig {
        point: point.clone(),
        device: *dev,
        inferences: 48,
        arrival_interval: None,
        rewind,
        overlap,
    });
    (r.latencies[0], r.steady_ii)
}

fn main() {
    let z = Device::by_name("zynq7045").unwrap();
    let u = Device::by_name("u250").unwrap();

    println!("=== ablation: rewind x overlap (cycle simulator, 48 inferences) ===\n");
    let mut t = Table::new(&[
        "design",
        "rewind",
        "overlap",
        "latency (cycles)",
        "steady II (cycles)",
        "II penalty",
    ]);
    for (label, point, dev) in [
        ("Z3 (small, balanced)", DesignPoint::small_autoencoder(9, 1, 8), z),
        ("U2 (nominal, balanced)", DesignPoint::nominal_autoencoder(9, 1, 8), u),
    ] {
        let (_, base_ii) = run(&point, dev, true, true);
        for (rw, ov) in [(true, true), (false, true), (true, false), (false, false)] {
            let (lat, ii) = run(&point, dev, rw, ov);
            t.row(&[
                label.into(),
                rw.to_string(),
                ov.to_string(),
                lat.to_string(),
                format!("{ii:.1}"),
                format!("{:+.0}%", 100.0 * (ii / base_ii - 1.0)),
            ]);
        }
    }
    t.print();

    println!("\n=== ablation: balanced vs unbalanced layer IIs at similar DSPs (Fig. 1/4) ===\n");
    let layers = vec![LayerDims::new(1, 9), LayerDims::new(9, 9)];
    // balanced: both layers rh=2 (ii=10 each)
    let balanced = DesignPoint {
        layers: layers.clone(),
        rx: vec![10, 10],
        rh: vec![2, 2],
        ts: 8,
        dense_out: 1,
    };
    // unbalanced: layer0 starved (rh=6), layer1 over-provisioned (rh=1)
    let unbalanced = DesignPoint {
        layers,
        rx: vec![10, 10],
        rh: vec![6, 1],
        ts: 8,
        dense_out: 1,
    };
    let mut t = Table::new(&["config", "DSPs", "II_sys (sim)", "layer0 ii", "layer1 ii"]);
    for (name, p) in [("balanced", &balanced), ("unbalanced", &unbalanced)] {
        let m = model_perf(z, p);
        let r = simulate(&SimConfig {
            point: p.clone(),
            device: *z,
            inferences: 48,
            arrival_interval: None,
            rewind: true,
            overlap: true,
        });
        t.row(&[
            name.into(),
            m.dsp_model.to_string(),
            format!("{:.1}", r.steady_ii),
            m.per_layer[0].ii.to_string(),
            m.per_layer[1].ii.to_string(),
        ]);
    }
    t.print();
    println!(
        "\nthe unbalanced design spends comparable DSPs but its system II is set\n\
         by the starved layer (Fig. 1); balancing equalizes layer IIs (Fig. 4)."
    );
}
