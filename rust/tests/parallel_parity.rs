//! Parallel-determinism parity: the balanced-partition worker pool must
//! change *nothing* numerically — only wall-clock.
//!
//! Contracts pinned here (the acceptance criteria of the parallel layer):
//!
//! 1. **Thread-count parity** — forward/score at threads ∈ {1, 2, 3, 8} ×
//!    B ∈ {1, 3, 8, 32} × both math tiers are bit-identical to the
//!    single-thread engine, through every entry point (stateless,
//!    stateful, streaming executor), including the evolved resident
//!    states.
//! 2. **Plan-mode parity** — even the deliberately imbalanced
//!    [`PlanMode::NaiveRows`] split is bit-identical (partitioning changes
//!    which core computes a stream row, never an operand or an
//!    accumulation order).
//! 3. **Streaming isolation under parallelism (property)** — randomized
//!    ragged hop schedules through a `StreamRouter` backed by a
//!    multi-threaded executor match isolated single-thread references
//!    bitwise.
//! 4. **Serving end-to-end** — `run_serving_native` and
//!    `run_serving_streaming` complete with `threads > 1` and report the
//!    `+par{N}` platform; the PJRT entry point *rejects* `threads != 1`.
//!
//! `GWLSTM_THREADS` (set by ci.sh to 1 and 4) widens the thread sweep so
//! the whole suite runs under both a serial and a parallel engine.

use gwlstm::config::{Manifest, ServeConfig};
use gwlstm::coordinator::{
    run_serving_native, run_serving_streaming, run_serving_with_policy, Policy, StreamRouter,
};
use gwlstm::model::batched::{BatchedState, LayerScratch};
use gwlstm::model::par::{threads_from_env, PlanMode, StagePlan, WorkerPool};
use gwlstm::model::weights::LstmWeights;
use gwlstm::model::{AutoencoderWeights, BatchedLstm, MathPolicy, PackedAutoencoder};
use gwlstm::runtime::ModelExecutor;
use gwlstm::stream::StreamConfig;
use gwlstm::util::prop;
use gwlstm::util::rng::Rng;

const BATCHES: [usize; 4] = [1, 3, 8, 32];
const TIERS: [MathPolicy; 2] = [MathPolicy::BitExact, MathPolicy::FastSimd];

/// The acceptance sweep {1, 2, 3, 8}, widened by GWLSTM_THREADS when ci.sh
/// (or a user) sets it.
fn thread_sweep() -> Vec<usize> {
    let mut ts = vec![1usize, 2, 3, 8];
    let env = threads_from_env(1);
    if !ts.contains(&env) {
        ts.push(env);
    }
    ts
}

fn random_layer(seed: u64, lx: usize, lh: usize) -> LstmWeights {
    let mut rng = Rng::new(seed);
    let mut gen = |n: usize, s: f64| -> Vec<f32> {
        (0..n).map(|_| (rng.gaussian() * s) as f32).collect()
    };
    LstmWeights {
        name: format!("par_{lx}x{lh}"),
        lx,
        lh,
        wx: gen(lx * 4 * lh, 0.4),
        wh: gen(lh * 4 * lh, 0.3),
        b: gen(4 * lh, 0.1),
    }
}

#[test]
fn stateless_forward_and_scores_bitidentical_at_every_thread_count() {
    let ts = 12usize;
    let w = AutoencoderWeights::synthetic(0x9A1, "small");
    let mut rng = Rng::new(0x9A2);
    let windows: Vec<f32> = (0..32 * ts).map(|_| rng.gaussian() as f32).collect();
    for policy in TIERS {
        let serial = PackedAutoencoder::from_weights_policy(&w, policy);
        for threads in thread_sweep() {
            let par = PackedAutoencoder::from_weights_policy_threads(&w, policy, threads);
            for &batch in &BATCHES {
                let win = &windows[..batch * ts];
                assert_eq!(
                    par.forward_batch(win, batch),
                    serial.forward_batch(win, batch),
                    "{policy:?} threads={threads} B={batch} forward diverged"
                );
                assert_eq!(
                    par.score_batch(win, batch),
                    serial.score_batch(win, batch),
                    "{policy:?} threads={threads} B={batch} scores diverged"
                );
            }
        }
    }
}

#[test]
fn stateful_chunked_runs_bitidentical_at_every_thread_count() {
    // Ragged hop schedule through the layer-level stateful twin: outputs
    // AND carried (h, c) must match the serial engine bit-for-bit.
    let (lx, lh, ts) = (2usize, 9usize, 12usize);
    let w = random_layer(0x9B1, lx, lh);
    let hops = [5usize, 1, 4, 2];
    assert_eq!(hops.iter().sum::<usize>(), ts);
    for policy in TIERS {
        let eng = BatchedLstm::from_weights_policy(&w, policy);
        for threads in thread_sweep() {
            let pool = WorkerPool::new(threads);
            for &batch in &BATCHES {
                let mut rng = Rng::new(0x9B2 + batch as u64);
                let xs: Vec<f32> = (0..batch * ts * lx)
                    .map(|_| rng.gaussian() as f32)
                    .collect();
                let mut st_serial = BatchedState::zeros(batch, lh);
                let mut st_par = BatchedState::zeros(batch, lh);
                let mut scratch = LayerScratch::default();
                let mut t0 = 0usize;
                for &hop in &hops {
                    let mut chunk = Vec::with_capacity(batch * hop * lx);
                    for b in 0..batch {
                        chunk.extend_from_slice(
                            &xs[(b * ts + t0) * lx..(b * ts + t0 + hop) * lx],
                        );
                    }
                    let want = eng.run_stateful(&chunk, batch, hop, &mut st_serial);
                    let mut got = Vec::new();
                    eng.run_stateful_into_pooled(
                        &chunk,
                        batch,
                        hop,
                        &mut scratch,
                        &mut got,
                        &mut st_par,
                        &pool,
                    );
                    assert_eq!(
                        got, want,
                        "{policy:?} threads={threads} B={batch} t0={t0} chunk diverged"
                    );
                    t0 += hop;
                }
                assert_eq!(st_par.h, st_serial.h, "{policy:?} threads={threads} h");
                assert_eq!(st_par.c, st_serial.c, "{policy:?} threads={threads} c");
            }
        }
    }
}

#[test]
fn streaming_executor_bitidentical_at_every_thread_count() {
    // The runtime-level streaming entry point (what StreamRouter drives):
    // stateful score sequences and final states across consecutive hops.
    let hop = 4usize;
    for policy in TIERS {
        let w = AutoencoderWeights::synthetic(0x9C1, "small");
        let serial =
            ModelExecutor::native_from_weights_policy_threads(&w, "par_ref", 8, policy, 1);
        for threads in thread_sweep() {
            let par =
                ModelExecutor::native_from_weights_policy_threads(&w, "par_ref", 8, policy, threads);
            for &batch in &BATCHES {
                let mut rng = Rng::new(0x9C2 + threads as u64);
                let mut st_serial = serial.stream_state(batch).unwrap();
                let mut st_par = par.stream_state(batch).unwrap();
                for tick in 0..3 {
                    let chunk: Vec<f32> = (0..batch * hop)
                        .map(|_| rng.gaussian() as f32)
                        .collect();
                    let want = serial
                        .score_batch_stateful(&chunk, batch, &mut st_serial)
                        .unwrap();
                    let got = par
                        .score_batch_stateful(&chunk, batch, &mut st_par)
                        .unwrap();
                    assert_eq!(
                        got, want,
                        "{policy:?} threads={threads} B={batch} tick={tick}"
                    );
                }
                for (l, (a, b)) in st_par.layers.iter().zip(&st_serial.layers).enumerate() {
                    assert_eq!(a.h, b.h, "{policy:?} threads={threads} layer {l} h");
                    assert_eq!(a.c, b.c, "{policy:?} threads={threads} layer {l} c");
                }
            }
        }
    }
}

#[test]
fn naive_plan_mode_is_bitexact_too() {
    // The imbalanced baseline split must only be slower, never different.
    let ts = 10usize;
    let w = AutoencoderWeights::synthetic(0x9D1, "small");
    let serial = PackedAutoencoder::from_weights(&w);
    let naive = PackedAutoencoder::from_weights_policy_pool(
        &w,
        MathPolicy::BitExact,
        WorkerPool::with_mode(8, PlanMode::NaiveRows),
    );
    let mut rng = Rng::new(0x9D2);
    let windows: Vec<f32> = (0..30 * ts).map(|_| rng.gaussian() as f32).collect();
    for &batch in &[1usize, 7, 30] {
        assert_eq!(
            naive.score_batch(&windows[..batch * ts], batch),
            serial.score_batch(&windows[..batch * ts], batch),
            "naive split diverged at B={batch}"
        );
    }
}

#[test]
fn stage_plan_balances_what_naive_does_not() {
    let dims = [(1usize, 9usize), (9, 9)];
    for batch in [1usize, 3, 8, 30, 32, 33] {
        for threads in [1usize, 2, 3, 8] {
            let bal = StagePlan::balanced(batch, threads, &dims);
            let nai = StagePlan::naive(batch, threads);
            // both partition the batch exactly
            for plan in [&bal, &nai] {
                let mut next = 0usize;
                for &(b0, rows) in plan.slices() {
                    assert_eq!(b0, next);
                    assert!(rows > 0);
                    next += rows;
                }
                assert_eq!(next, batch);
            }
            assert!(bal.max_cost(&dims) <= nai.max_cost(&dims));
        }
    }
    // the motivating shape: naive's 9-row tail = 3x the balanced bottleneck
    let bal = StagePlan::balanced(30, 8, &dims);
    let nai = StagePlan::naive(30, 8);
    assert_eq!(nai.max_cost(&dims), 3 * bal.max_cost(&dims));
}

/// One randomized scenario: per-session chunk sequences plus an arrival
/// schedule, replayed through a parallel-engine router vs isolated
/// single-thread references.
#[derive(Debug)]
struct ParInterleaving {
    hop: usize,
    threads: usize,
    chunks: Vec<Vec<Vec<f32>>>,
    schedule: Vec<Vec<usize>>,
}

#[test]
fn prop_parallel_router_matches_isolated_single_thread_references() {
    let w = AutoencoderWeights::synthetic(0x9E1, "small");
    let solo_exe = ModelExecutor::native_from_weights(&w, "par_prop_ref", 8);
    prop::check_with(
        prop::Config {
            cases: 16, // each case runs many engine calls; keep the suite fast
            ..Default::default()
        },
        "parallel-router-matches-single-thread",
        |d| {
            let hop = d.usize_in(2, 6);
            let threads = d.usize_in(2, 6);
            let n_sessions = d.usize_in(2, 5);
            let chunks: Vec<Vec<Vec<f32>>> = (0..n_sessions)
                .map(|_| {
                    let n_chunks = d.usize_in(1, 4);
                    (0..n_chunks)
                        .map(|_| (0..hop).map(|_| d.f64_in(-2.0, 2.0) as f32).collect())
                        .collect()
                })
                .collect();
            // random arrival order, partitioned into ticks (a session
            // appears at most once per tick — one chunk per dispatch)
            let mut arrivals: Vec<usize> = Vec::new();
            for (s, cs) in chunks.iter().enumerate() {
                arrivals.extend(std::iter::repeat(s).take(cs.len()));
            }
            for i in (1..arrivals.len()).rev() {
                let j = d.usize_in(0, i);
                arrivals.swap(i, j);
            }
            let mut schedule: Vec<Vec<usize>> = Vec::new();
            while !arrivals.is_empty() {
                let width = d.usize_in(1, arrivals.len().min(n_sessions));
                let mut tick: Vec<usize> = Vec::new();
                let mut remaining: Vec<usize> = Vec::new();
                for &s in &arrivals {
                    if tick.len() < width && !tick.contains(&s) {
                        tick.push(s);
                    } else {
                        remaining.push(s);
                    }
                }
                arrivals = remaining;
                schedule.push(tick);
            }
            ParInterleaving {
                hop,
                threads,
                chunks,
                schedule,
            }
        },
        |case| {
            let cfg = StreamConfig {
                hop: case.hop,
                ..Default::default()
            };
            // shared router backed by a PARALLEL engine
            let par_exe = ModelExecutor::native_from_weights_policy_threads(
                &w,
                "par_prop",
                8,
                MathPolicy::BitExact,
                case.threads,
            );
            let mut shared = StreamRouter::new(&par_exe, cfg).map_err(|e| e.to_string())?;
            let mut got: Vec<Vec<f32>> = vec![Vec::new(); case.chunks.len()];
            let mut next_chunk: Vec<usize> = vec![0; case.chunks.len()];
            for (tick, sessions) in case.schedule.iter().enumerate() {
                for &s in sessions {
                    let c = &case.chunks[s][next_chunk[s]];
                    next_chunk[s] += 1;
                    shared.ingest(s as u64, c, tick as u64);
                }
                for sc in shared
                    .dispatch(&par_exe, tick as u64)
                    .map_err(|e| e.to_string())?
                {
                    got[sc.stream as usize].push(sc.score);
                }
            }
            // isolated single-thread references
            for (s, cs) in case.chunks.iter().enumerate() {
                let mut solo = StreamRouter::new(&solo_exe, cfg).map_err(|e| e.to_string())?;
                let mut want: Vec<f32> = Vec::new();
                for (tick, c) in cs.iter().enumerate() {
                    solo.ingest(s as u64, c, tick as u64);
                    for sc in solo
                        .dispatch(&solo_exe, tick as u64)
                        .map_err(|e| e.to_string())?
                    {
                        want.push(sc.score);
                    }
                }
                if got[s] != want {
                    return Err(format!(
                        "threads={}: session {s} grouped scores {:?} != isolated \
                         single-thread {:?}",
                        case.threads, got[s], want
                    ));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn native_serving_end_to_end_with_threads() {
    let weights = AutoencoderWeights::synthetic(0xD0E, "small");
    let threads = threads_from_env(3);
    let cfg = ServeConfig {
        model: "small_par".into(),
        calib_windows: 24,
        max_windows: 96,
        inject_prob: 0.3,
        threads,
        ..Default::default()
    };
    let report = run_serving_native(&weights, 8, &cfg, Policy::Immediate).unwrap();
    assert_eq!(report.windows, 96);
    if threads > 1 {
        assert!(
            report.platform.contains(&format!("par{threads}")),
            "platform {} must advertise the lane count",
            report.platform
        );
    }
    assert!(report.auc > 0.0 && report.auc <= 1.0);
}

#[test]
fn streaming_serving_end_to_end_with_threads_matches_single_thread_scores() {
    // Same synthetic feeds, same config modulo threads: the two serving
    // runs must produce identical thresholds and AUC (scores are
    // bit-identical, and the deterministic feeds replay exactly).
    let weights = AutoencoderWeights::synthetic(0xD0E, "small");
    let mk = |threads: usize| ServeConfig {
        model: "small_par_stream".into(),
        calib_windows: 16,
        max_windows: 48,
        inject_prob: 0.3,
        stream_sessions: 4,
        stream_hop: 8,
        streaming: true,
        threads,
        ..Default::default()
    };
    let one = run_serving_streaming(&weights, &mk(1)).unwrap();
    let par = run_serving_streaming(&weights, &mk(3)).unwrap();
    assert_eq!(par.windows, one.windows);
    assert_eq!(par.threshold, one.threshold, "calibration diverged");
    assert_eq!(par.auc, one.auc, "served score distribution diverged");
    assert!(par.platform.contains("par3"), "{}", par.platform);
}

#[test]
fn pjrt_entry_point_rejects_threads() {
    // Reject-don't-ignore: the PJRT pipeline has no worker pool, so an
    // explicit threads request must error before any artifact is touched.
    let manifest = Manifest {
        dir: ".".into(),
        variants: vec![],
    };
    let cfg = ServeConfig {
        threads: 4,
        ..Default::default()
    };
    let err = run_serving_with_policy(&manifest, &cfg, Policy::Immediate)
        .expect_err("threads != 1 must be rejected under PJRT");
    assert!(
        err.to_string().contains("native"),
        "error should point at the native backend: {err}"
    );
}
