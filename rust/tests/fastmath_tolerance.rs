//! The FastSimd accuracy contract, pinned: `MathPolicy::FastSimd` outputs
//! must stay within the tolerances stated in `model::simd`
//! (`FAST_LAYER_TOL` for a single LSTM layer, `FAST_FORWARD_TOL` for full
//! autoencoder reconstructions and anomaly scores) of the `BitExact`
//! engine — on random windows, on chirp-injected `gw::dataset` windows,
//! for every serving batch size B ∈ {1, 3, 8}, and for ragged hidden
//! widths not divisible by the 16-lane block width or the 8-lane vector
//! width.
//!
//! Also pinned here: the BitExact tier of the register-blocked kernel is
//! *bit-identical* to the unblocked PR 1 kernel for every tile width and
//! every row-remainder (RB) configuration — blocking moves accumulators
//! into registers, it must never reorder a reduction.

use gwlstm::gw::dataset::{make_dataset, DEFAULT_SNR};
use gwlstm::model::batched::{BatchedLstm, PackedMatrix, GEMM_RB};
use gwlstm::model::simd::{FAST_FORWARD_TOL, FAST_LAYER_TOL};
use gwlstm::model::weights::LstmWeights;
use gwlstm::model::{AutoencoderWeights, MathPolicy, PackedAutoencoder};
use gwlstm::util::prop::{check_with, Config};
use gwlstm::util::rng::Rng;

const BATCHES: [usize; 3] = [1, 3, 8];

fn random_layer(seed: u64, lx: usize, lh: usize) -> LstmWeights {
    let mut rng = Rng::new(seed);
    let mut gen = |n: usize, s: f64| -> Vec<f32> {
        (0..n).map(|_| (rng.gaussian() * s) as f32).collect()
    };
    LstmWeights {
        name: format!("fast_{lx}x{lh}"),
        lx,
        lh,
        wx: gen(lx * 4 * lh, 0.4),
        wh: gen(lh * 4 * lh, 0.3),
        b: gen(4 * lh, 0.1),
    }
}

fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len(), "length mismatch");
    a.iter()
        .zip(b)
        .map(|(x, y)| (x - y).abs())
        .fold(0.0f32, f32::max)
}

#[test]
fn prop_fast_layer_within_tolerance_on_random_windows() {
    // Random layer shapes with deliberately ragged Lh (1..=37 covers
    // lh % 8 != 0, lh % 16 != 0, and 4*lh % 16 != 0 cases), random inputs,
    // all serving batch sizes.
    check_with(
        Config {
            cases: 40,
            ..Default::default()
        },
        "fast-layer-tolerance",
        |d| {
            let lx = d.usize_in(1, 8);
            let lh = d.usize_in(1, 37);
            let ts = d.usize_in(1, 20);
            let seed = d.usize_in(0, 1 << 20) as u64;
            (lx, lh, ts, seed)
        },
        |&(lx, lh, ts, seed)| {
            let w = random_layer(seed, lx, lh);
            let exact = BatchedLstm::from_weights(&w);
            let fast = BatchedLstm::from_weights_policy(&w, MathPolicy::FastSimd);
            for &batch in &BATCHES {
                let mut rng = Rng::new(seed ^ 0xFA57);
                let xs: Vec<f32> = (0..batch * ts * lx)
                    .map(|_| rng.gaussian() as f32)
                    .collect();
                let a = exact.run(&xs, batch, ts);
                let b = fast.run(&xs, batch, ts);
                let err = max_abs_diff(&a, &b);
                if err > FAST_LAYER_TOL {
                    return Err(format!(
                        "lx={lx} lh={lh} ts={ts} B={batch}: max err {err} > {FAST_LAYER_TOL}"
                    ));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn fast_autoencoder_within_tolerance_on_random_windows() {
    for arch in ["small", "nominal"] {
        let w = AutoencoderWeights::synthetic(31, arch);
        let exact = PackedAutoencoder::from_weights(&w);
        let fast = PackedAutoencoder::from_weights_policy(&w, MathPolicy::FastSimd);
        let ts = if arch == "small" { 8 } else { 24 };
        for &batch in &BATCHES {
            let mut rng = Rng::new(0xFA + batch as u64);
            let windows: Vec<f32> = (0..batch * ts).map(|_| rng.gaussian() as f32).collect();
            let a = exact.forward_batch(&windows, batch);
            let b = fast.forward_batch(&windows, batch);
            let err = max_abs_diff(&a, &b);
            assert!(
                err <= FAST_FORWARD_TOL,
                "{arch} B={batch}: max err {err} > {FAST_FORWARD_TOL}"
            );
        }
    }
}

#[test]
fn fast_autoencoder_within_tolerance_on_chirp_windows() {
    // Real substrate: chirp-injected windows from the dataset twin through
    // the nominal architecture at its native TS=100 (the worst case for
    // per-step activation-error compounding).
    let ts = 100;
    let w = AutoencoderWeights::synthetic(37, "nominal");
    let exact = PackedAutoencoder::from_weights(&w);
    let fast = PackedAutoencoder::from_weights_policy(&w, MathPolicy::FastSimd);
    let events = make_dataset(0xFA57C, 8, ts, DEFAULT_SNR);
    assert!(events.iter().any(|e| e.label == 1), "need injected windows");
    let flat: Vec<f32> = events.iter().flat_map(|e| e.samples.clone()).collect();
    for &batch in &BATCHES {
        let a = exact.forward_batch(&flat[..batch * ts], batch);
        let b = fast.forward_batch(&flat[..batch * ts], batch);
        let err = max_abs_diff(&a, &b);
        assert!(
            err <= FAST_FORWARD_TOL,
            "chirp B={batch}: max err {err} > {FAST_FORWARD_TOL}"
        );
        // ... and the anomaly scores the detector actually thresholds.
        let sa = exact.score_batch(&flat[..batch * ts], batch);
        let sb = fast.score_batch(&flat[..batch * ts], batch);
        for (i, (x, y)) in sa.iter().zip(&sb).enumerate() {
            assert!(
                (x - y).abs() <= FAST_FORWARD_TOL,
                "chirp B={batch} score {i}: {x} vs {y}"
            );
        }
    }
}

#[test]
fn prop_bitexact_blocked_gemm_equals_unblocked_every_configuration() {
    // Tile × rows sweep: every panel-width class (full 16-wide blocks,
    // ragged tails, tiles narrower and wider than the block) crossed with
    // every row-remainder class of the RB blocking must be bit-identical
    // to the PR 1 row-wise kernel.
    check_with(
        Config {
            cases: 60,
            ..Default::default()
        },
        "blocked-gemm-bitexact-sweep",
        |d| {
            let k = d.usize_in(1, 24);
            let n = d.usize_in(1, 70);
            let rows = d.usize_in(1, 2 * GEMM_RB + 3);
            let tile = [1, 2, 3, 5, 8, 16, 24, 64][d.usize_in(0, 7)];
            let seed = d.usize_in(0, 1 << 20) as u64;
            (k, n, rows, tile, seed)
        },
        |&(k, n, rows, tile, seed)| {
            let mut rng = Rng::new(seed);
            let src: Vec<f32> = (0..k * n).map(|_| rng.gaussian() as f32).collect();
            let x: Vec<f32> = (0..rows * k).map(|_| rng.gaussian() as f32).collect();
            let m = PackedMatrix::pack_with_tile(&src, k, n, tile);
            let mut z_blocked: Vec<f32> = (0..rows * n).map(|_| rng.gaussian() as f32).collect();
            let mut z_rowwise = z_blocked.clone();
            m.gemm_acc(&x, rows, &mut z_blocked);
            m.gemm_acc_unblocked(&x, rows, &mut z_rowwise);
            if z_blocked != z_rowwise {
                return Err(format!("k={k} n={n} rows={rows} tile={tile} diverged"));
            }
            Ok(())
        },
    );
}

#[test]
fn bitexact_layer_identical_for_every_row_remainder() {
    // Layer-level RB sweep: every batch size through one full RB block
    // plus remainder (1..=2*RB+1) must be bit-identical per stream to the
    // scalar reference — the layer path exercises both the (B*TS)-row xw
    // GEMM and the B-row recurrent GEMM blockings.
    let w = random_layer(51, 3, 16);
    let exact = BatchedLstm::from_weights(&w);
    let ts = 6;
    let mut rng = Rng::new(52);
    let max_b = 2 * GEMM_RB + 1;
    let xs: Vec<f32> = (0..max_b * ts * 3).map(|_| rng.gaussian() as f32).collect();
    let singles: Vec<Vec<f32>> = (0..max_b)
        .map(|b| {
            gwlstm::model::lstm::lstm_layer(&w, &xs[b * ts * 3..(b + 1) * ts * 3], ts)
        })
        .collect();
    for batch in 1..=max_b {
        let got = exact.run(&xs[..batch * ts * 3], batch, ts);
        for (b, single) in singles.iter().enumerate().take(batch) {
            assert_eq!(
                &got[b * ts * 16..(b + 1) * ts * 16],
                &single[..],
                "B={batch} stream {b}"
            );
        }
    }
}
