//! Quantized-tier parity + accuracy contracts: the Q6.10 lockstep serving
//! engine must be **bitwise** the scalar fixed-point reference, and
//! accuracy-**bounded** against the BitExact f32 tier.
//!
//! Contracts pinned here (the acceptance criteria of the quantized tier):
//!
//! 1. **Scalar/batched parity** — `FixedBatchedLstm` and
//!    `FixedPackedAutoencoder` outputs at B ∈ {1, 3, 8, 32} × threads
//!    {1, 4}, on chirp-injected and random windows, are bit-identical to
//!    the scalar `FixedLstm`/`FixedAutoencoder` (exact i64 gate totals:
//!    blocking, batching and threading are order-free transforms).
//! 2. **Chunk parity** — stateful continuation over ragged hop schedules
//!    is bit-identical to one contiguous run (integer state carries
//!    exactly).
//! 3. **Isolation (property)** — randomized session interleavings through
//!    a `StreamRouter` backed by a quantized executor match isolated
//!    scalar-engine references bitwise.
//! 4. **Accuracy bounds** — per-window score drift and ROC-AUC drift vs
//!    the BitExact tier on the chirp dataset stay within
//!    `QUANT_SCORE_TOL` / `QUANT_AUC_TOL` (`eval::roc::tier_accuracy`).
//! 5. **Serving** — `streaming + ingress + shards` under
//!    `MathPolicy::Quantized` closes the conservation ledger end-to-end
//!    and reports the `q16` platform; the PJRT entry point *rejects* the
//!    quantized tier (reject-don't-ignore).
//! 6. **Cross-language goldens** — `to_q16`/`to_q32` (half away from
//!    zero) and the i64 GEMM accumulation match the shared golden vectors
//!    that `python/tests/test_quant.py` pins on the numpy side.

use gwlstm::config::{Manifest, ServeConfig};
use gwlstm::coordinator::{
    run_serving_streaming, run_serving_with_policy, Policy, ShardLedger, StreamRouter,
};
use gwlstm::eval::roc::tier_accuracy;
use gwlstm::gw::dataset::{make_dataset, DEFAULT_SNR};
use gwlstm::model::act_lut::SigmoidLut;
use gwlstm::model::fixed::{
    to_q16, FixedBatchedLstm, FixedBatchedState, FixedLstm, FixedPackedAutoencoder,
    PackedMatrixI16, QUANT_AUC_TOL, QUANT_SCORE_TOL,
};
use gwlstm::model::weights::LstmWeights;
use gwlstm::model::{AutoencoderWeights, FixedAutoencoder, MathPolicy, PackedAutoencoder, WorkerPool};
use gwlstm::runtime::ModelExecutor;
use gwlstm::stream::StreamConfig;
use gwlstm::util::prop;
use gwlstm::util::rng::Rng;

const BATCHES: [usize; 4] = [1, 3, 8, 32];
const THREADS: [usize; 2] = [1, 4];

fn random_layer(seed: u64, lx: usize, lh: usize) -> LstmWeights {
    let mut rng = Rng::new(seed);
    let mut gen = |n: usize, s: f64| -> Vec<f32> {
        (0..n).map(|_| (rng.gaussian() * s) as f32).collect()
    };
    LstmWeights {
        name: format!("fixed_{lx}x{lh}"),
        lx,
        lh,
        wx: gen(lx * 4 * lh, 0.4),
        wh: gen(lh * 4 * lh, 0.3),
        b: gen(4 * lh, 0.1),
    }
}

/// Chirp-injected windows quantized to the Q6.10 input grid, flattened
/// batch-major (`n` windows of `ts` samples each).
fn chirp_q16(seed: u64, n: usize, ts: usize) -> Vec<i16> {
    let events = make_dataset(seed, n, ts, DEFAULT_SNR);
    assert!(events.iter().any(|e| e.label == 1), "need injected windows");
    events
        .iter()
        .flat_map(|e| e.samples.iter().map(|&v| to_q16(v)))
        .collect()
}

#[test]
fn batched_quantized_lstm_bitexact_with_scalar_reference() {
    // Contract 1 at the layer level: chirp + random substrates, every
    // serving batch size, both thread counts. lh = 9 exercises the ragged
    // panel tail (4*9 = 36 = 2*16 + 4); a second ragged-width layer below
    // covers lh not divisible by anything convenient.
    let lut = SigmoidLut::default();
    let ts = 20usize;
    for (seed, lx, lh) in [(0xF1u64, 1usize, 9usize), (0xF2, 3, 17)] {
        let w = random_layer(seed, lx, lh);
        let scalar = FixedLstm::from_weights(&w);
        let packed = FixedBatchedLstm::from_weights(&w);
        let mut substrates: Vec<Vec<i16>> = Vec::new();
        if lx == 1 {
            substrates.push(chirp_q16(0xF1DE, 32, ts));
        }
        let mut rng = Rng::new(seed ^ 0x0F1F);
        substrates.push(
            (0..32 * ts * lx)
                .map(|_| to_q16(rng.gaussian() as f32))
                .collect(),
        );
        for xs in &substrates {
            for &batch in &BATCHES {
                let slice = &xs[..batch * ts * lx];
                let got = packed.run(&lut, slice, batch, ts);
                for b in 0..batch {
                    let one = scalar.run(&lut, &slice[b * ts * lx..(b + 1) * ts * lx], ts);
                    assert_eq!(
                        &got[b * ts * lh..(b + 1) * ts * lh],
                        &one[..],
                        "lx={lx} lh={lh} B={batch} stream {b}"
                    );
                }
                for &threads in &THREADS {
                    let pool = WorkerPool::new(threads);
                    assert_eq!(
                        packed.run_pooled(&lut, slice, batch, ts, &pool),
                        got,
                        "lx={lx} lh={lh} B={batch} threads={threads}"
                    );
                }
            }
        }
    }
}

#[test]
fn quantized_autoencoder_bitexact_with_scalar_through_executor() {
    // Contract 1 at the serving-engine level, through the ModelExecutor
    // the coordinator actually calls: reconstructions AND scores equal the
    // scalar FixedAutoencoder per stream at every (B, threads).
    let ts = 8usize;
    let w = AutoencoderWeights::synthetic(0xF3, "small");
    let scalar = FixedAutoencoder::from_weights(&w);
    let events = make_dataset(0xF3DE, 32, ts, DEFAULT_SNR);
    let chirp: Vec<f32> = events.iter().flat_map(|e| e.samples.clone()).collect();
    let mut rng = Rng::new(0xF3F4);
    let random: Vec<f32> = (0..32 * ts).map(|_| rng.gaussian() as f32).collect();
    for &threads in &THREADS {
        let exe = ModelExecutor::native_from_weights_policy_threads(
            &w,
            "fixed_parity",
            ts,
            MathPolicy::Quantized,
            threads,
        );
        assert!(exe.platform().contains("q16"), "{}", exe.platform());
        for windows in [&chirp, &random] {
            for &batch in &BATCHES {
                let slice = &windows[..batch * ts];
                let rec = exe.infer_batch(slice, batch).unwrap();
                let scores = exe.score_batch(slice, batch).unwrap();
                for b in 0..batch {
                    let window = &slice[b * ts..(b + 1) * ts];
                    assert_eq!(
                        &rec[b * ts..(b + 1) * ts],
                        &scalar.forward(window)[..],
                        "threads={threads} B={batch} stream {b}"
                    );
                    assert_eq!(scores[b], scalar.score(window), "threads={threads} B={batch} score {b}");
                }
            }
        }
    }
}

#[test]
fn quantized_chunked_stateful_bitexact_over_ragged_hops() {
    // Contract 2 at the layer level: ragged hop schedules over a stateful
    // lockstep group equal one contiguous run, bit for bit.
    let lut = SigmoidLut::default();
    let ts = 24usize;
    let schedules: [&[usize]; 4] = [&[24], &[1; 24], &[5, 1, 9, 2, 7], &[11, 13]];
    for (seed, lx, lh) in [(0xF5u64, 1usize, 9usize), (0xF6, 2, 8)] {
        let w = random_layer(seed, lx, lh);
        let packed = FixedBatchedLstm::from_weights(&w);
        for batch in [1usize, 3, 8] {
            let mut rng = Rng::new(seed ^ 0x5EED);
            let xs: Vec<i16> = (0..batch * ts * lx)
                .map(|_| to_q16(rng.gaussian() as f32))
                .collect();
            let contiguous = packed.run(&lut, &xs, batch, ts);
            for hops in schedules {
                let mut st = FixedBatchedState::zeros(batch, lh);
                let mut got = vec![0i16; batch * ts * lh];
                let mut t0 = 0usize;
                for &hop in hops {
                    let mut chunk = vec![0i16; batch * hop * lx];
                    for b in 0..batch {
                        chunk[b * hop * lx..(b + 1) * hop * lx].copy_from_slice(
                            &xs[(b * ts + t0) * lx..(b * ts + t0 + hop) * lx],
                        );
                    }
                    let part = packed.run_stateful(&lut, &chunk, batch, hop, &mut st);
                    for b in 0..batch {
                        got[(b * ts + t0) * lh..(b * ts + t0 + hop) * lh]
                            .copy_from_slice(&part[b * hop * lh..(b + 1) * hop * lh]);
                    }
                    t0 += hop;
                }
                assert_eq!(got, contiguous, "lx={lx} lh={lh} B={batch} hops={hops:?}");
            }
        }
    }
}

#[test]
fn quantized_stateful_groups_isolate_streams_at_any_thread_count() {
    // Lockstep grouping + threading must not couple streams: a B-stream
    // stateful group scores exactly like B isolated batch-1 sessions on a
    // serial engine, chunk after chunk, with bit-equal evolved states.
    let ts = 8usize;
    let hop = 4usize;
    let batch = 5usize;
    let w = AutoencoderWeights::synthetic(0xF7, "small");
    let reference = FixedPackedAutoencoder::from_weights(&w);
    for &threads in &THREADS {
        let exe = ModelExecutor::native_from_weights_policy_threads(
            &w,
            "fixed_iso",
            ts,
            MathPolicy::Quantized,
            threads,
        );
        let mut group = exe.stream_state(batch).unwrap();
        let mut solos: Vec<_> = (0..batch).map(|_| reference.zero_state(1)).collect();
        let mut rng = Rng::new(0xF7F8);
        for tick in 0..4 {
            let chunks: Vec<Vec<f32>> = (0..batch)
                .map(|_| (0..hop).map(|_| rng.gaussian() as f32).collect())
                .collect();
            let flat: Vec<f32> = chunks.concat();
            let scores = exe.score_batch_stateful(&flat, batch, &mut group).unwrap();
            for (s, chunk) in chunks.iter().enumerate() {
                let want = reference.score_batch_stateful(chunk, 1, &mut solos[s]);
                assert_eq!(
                    scores[s], want[0],
                    "threads={threads} tick={tick} stream {s}"
                );
            }
        }
        let gq = group.quant.as_ref().expect("quantized resident state");
        for (s, solo) in solos.iter().enumerate() {
            let sq = solo.quant.as_ref().unwrap();
            for (l, (gl, sl)) in gq.layers.iter().zip(&sq.layers).enumerate() {
                let lh = gl.lh;
                assert_eq!(&gl.h[s * lh..(s + 1) * lh], &sl.h[..], "h stream {s} layer {l}");
                assert_eq!(&gl.c[s * lh..(s + 1) * lh], &sl.c[..], "c stream {s} layer {l}");
            }
        }
    }
}

/// One randomized interleaving scenario for the quantized isolation
/// property (same shape as `streaming_parity.rs`).
#[derive(Debug)]
struct Interleaving {
    hop: usize,
    chunks: Vec<Vec<Vec<f32>>>,
    schedule: Vec<Vec<usize>>,
}

#[test]
fn prop_quantized_interleaved_sessions_match_isolated_scalar_references() {
    // Contract 3: the StreamRouter on a quantized engine never crosses
    // session states — per-session score sequences are bitwise what an
    // isolated scalar-engine session produces, under randomized
    // arrival interleavings and lockstep groupings.
    let w = AutoencoderWeights::synthetic(0xF9, "small");
    let exe = ModelExecutor::native_from_weights_policy(&w, "fixed_prop", 8, MathPolicy::Quantized);
    let reference = FixedPackedAutoencoder::from_weights(&w);
    prop::check_with(
        prop::Config {
            cases: 16, // each case runs many engine calls; keep the suite fast
            ..Default::default()
        },
        "quantized-interleaved-sessions-isolated",
        |d| {
            let hop = d.usize_in(2, 6);
            let n_sessions = d.usize_in(2, 5);
            let chunks: Vec<Vec<Vec<f32>>> = (0..n_sessions)
                .map(|_| {
                    let n_chunks = d.usize_in(1, 4);
                    (0..n_chunks)
                        .map(|_| (0..hop).map(|_| d.f64_in(-2.0, 2.0) as f32).collect())
                        .collect()
                })
                .collect();
            let mut arrivals: Vec<usize> = Vec::new();
            for (s, cs) in chunks.iter().enumerate() {
                arrivals.extend(std::iter::repeat(s).take(cs.len()));
            }
            for i in (1..arrivals.len()).rev() {
                let j = d.usize_in(0, i);
                arrivals.swap(i, j);
            }
            let mut schedule: Vec<Vec<usize>> = Vec::new();
            while !arrivals.is_empty() {
                let width = d.usize_in(1, arrivals.len().min(n_sessions));
                let mut tick: Vec<usize> = Vec::new();
                let mut remaining: Vec<usize> = Vec::new();
                for &s in &arrivals {
                    if tick.len() < width && !tick.contains(&s) {
                        tick.push(s);
                    } else {
                        remaining.push(s);
                    }
                }
                arrivals = remaining;
                schedule.push(tick);
            }
            Interleaving {
                hop,
                chunks,
                schedule,
            }
        },
        |case| {
            let cfg = StreamConfig {
                hop: case.hop,
                ..Default::default()
            };
            let mut shared = StreamRouter::new(&exe, cfg).map_err(|e| e.to_string())?;
            let mut got: Vec<Vec<f32>> = vec![Vec::new(); case.chunks.len()];
            let mut next_chunk: Vec<usize> = vec![0; case.chunks.len()];
            for (tick, sessions) in case.schedule.iter().enumerate() {
                for &s in sessions {
                    let c = &case.chunks[s][next_chunk[s]];
                    next_chunk[s] += 1;
                    shared.ingest(s as u64, c, tick as u64);
                }
                for sc in shared.dispatch(&exe, tick as u64).map_err(|e| e.to_string())? {
                    got[sc.stream as usize].push(sc.score);
                }
            }
            // isolated scalar reference: one serial quantized engine,
            // batch-1 resident state per session
            for (s, cs) in case.chunks.iter().enumerate() {
                let mut st = reference.zero_state(1);
                let want: Vec<f32> = cs
                    .iter()
                    .map(|c| reference.score_batch_stateful(c, 1, &mut st)[0])
                    .collect();
                if got[s] != want {
                    return Err(format!(
                        "session {s}: routed scores {:?} != isolated {:?}",
                        got[s], want
                    ));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn quantized_tier_accuracy_bounded_vs_bitexact_on_chirp() {
    // Contract 4: the paper's "quantization has negligible effect", as
    // testable numbers — per-window score drift and AUC drift vs BitExact
    // on chirp-injected windows, at the nominal arch's native TS = 100
    // (worst case for per-step quantization-error compounding).
    let ts = 100usize;
    let n = 24usize;
    let w = AutoencoderWeights::synthetic(37, "nominal");
    let exact = PackedAutoencoder::from_weights(&w);
    let quant = FixedPackedAutoencoder::from_weights(&w);
    let events = make_dataset(0xFA57C, n, ts, DEFAULT_SNR);
    let labels: Vec<u8> = events.iter().map(|e| e.label).collect();
    assert!(labels.iter().any(|&l| l == 1) && labels.iter().any(|&l| l == 0));
    let flat: Vec<f32> = events.iter().flat_map(|e| e.samples.clone()).collect();
    let e_scores: Vec<f64> = exact
        .score_batch(&flat, n)
        .into_iter()
        .map(f64::from)
        .collect();
    let q_scores: Vec<f64> = quant
        .score_batch(&flat, n)
        .into_iter()
        .map(f64::from)
        .collect();
    let acc = tier_accuracy(&q_scores, &e_scores, &labels);
    assert!(
        acc.max_score_diff <= QUANT_SCORE_TOL as f64,
        "score drift {} > {QUANT_SCORE_TOL}",
        acc.max_score_diff
    );
    assert!(
        acc.auc_drift() <= QUANT_AUC_TOL,
        "AUC drift {} (q {} vs exact {}) > {QUANT_AUC_TOL}",
        acc.auc_drift(),
        acc.auc,
        acc.ref_auc
    );
}

#[test]
fn quantized_streaming_ingress_sharded_serving_conserves() {
    // Contract 5, the acceptance criterion: streaming + ingress + shards
    // under the quantized tier closes the conservation ledger end-to-end.
    let weights = AutoencoderWeights::synthetic(0xD0E, "small");
    let cfg = ServeConfig {
        model: "small_q16".into(),
        calib_windows: 16,
        max_windows: 64,
        inject_prob: 0.4,
        stream_sessions: 6,
        stream_hop: 8,
        streaming: true,
        ingress: true,
        shards: 2,
        math_policy: MathPolicy::Quantized,
        ..Default::default()
    };
    let report = run_serving_streaming(&weights, &cfg).unwrap();
    assert!(report.platform.contains("q16"), "{}", report.platform);
    assert!(report.windows >= cfg.max_windows, "quota not served");
    assert_eq!(
        report.ingested,
        report.windows as u64 + report.dropped + report.quarantined,
        "windows leaked: ingested {} != served {} + dropped {} + quarantined {}",
        report.ingested,
        report.windows,
        report.dropped,
        report.quarantined
    );
    assert_eq!(report.sheds.total(), report.dropped, "shed classes must sum");
    // per-shard ledgers conserve and roll up to the global ledger
    assert_eq!(report.shard_ledgers.len(), 2);
    for l in &report.shard_ledgers {
        assert!(l.conserved(), "shard {} ledger leaked", l.shard);
    }
    let total = report
        .shard_ledgers
        .iter()
        .fold(ShardLedger::default(), |a, l| a.plus(l));
    assert_eq!(total.ingested, report.ingested, "ingested sum drifted");
    assert_eq!(total.served, report.windows as u64, "served sum drifted");
    assert_eq!(total.dropped(), report.dropped, "dropped sum drifted");
    assert!(report.auc > 0.0 && report.auc <= 1.0);
}

#[test]
fn pjrt_entry_point_rejects_quantized_math() {
    // Reject-don't-ignore: the compiled artifact fixes its own math — an
    // explicit quantized request must error before any artifact is
    // touched, exactly like fast_simd and --threads do.
    let manifest = Manifest {
        dir: ".".into(),
        variants: vec![],
    };
    let cfg = ServeConfig {
        math_policy: MathPolicy::Quantized,
        ..Default::default()
    };
    let err = run_serving_with_policy(&manifest, &cfg, Policy::Immediate)
        .expect_err("quantized math must be rejected under PJRT");
    assert!(
        err.to_string().contains("native"),
        "error should point at the native backend: {err}"
    );
}

#[test]
fn cross_language_quantizer_goldens() {
    // Contract 6: the shared golden vectors (also asserted by the numpy
    // twin in python/tests/test_quant.py). Ties round half AWAY FROM ZERO:
    // 0.5 lsb -> 1, 2.5 lsb -> 3 — round-half-to-even would give 0 and 2,
    // so any silent drift back to banker's rounding fails here.
    let q16_golden: [(f32, i16); 13] = [
        (0.0, 0),
        (0.5 / 1024.0, 1),
        (-0.5 / 1024.0, -1),
        (2.5 / 1024.0, 3),
        (-2.5 / 1024.0, -3),
        (1.5 / 1024.0, 2),
        (0.25, 256),
        (-1.0, -1024),
        (32767.0 / 1024.0, 32767),
        (32.0, 32767), // 32 * 1024 = 32768 saturates
        (-32.0, -32768),
        (40.0, 32767),
        (-40.0, -32768),
    ];
    for &(x, want) in &q16_golden {
        assert_eq!(to_q16(x), want, "to_q16({x})");
    }
    let scale32 = (1u32 << 20) as f64;
    let q32_golden: [(f32, i32); 9] = [
        (0.0, 0),
        ((0.5 / scale32) as f32, 1),
        ((-0.5 / scale32) as f32, -1),
        ((2.5 / scale32) as f32, 3),
        (1.2345, 1_294_467),
        (-1.2345, -1_294_467),
        (2048.0, i32::MAX), // 2048 * 2^20 = 2^31 saturates
        (-2048.0, i32::MIN),
        (2047.9999, 2_147_483_520),
    ];
    for &(x, want) in &q32_golden {
        assert_eq!(gwlstm::model::fixed::to_q32(x), want, "to_q32({x})");
    }
    // i64 accumulation at the i16 extremes: exact, no intermediate
    // saturation (the numpy twin computes the same numbers in int64)
    let w = PackedMatrixI16::pack(&[32767, -32768, 1, -32768, 32767, -1], 2, 3);
    let mut z = vec![7i64; 3];
    w.gemm_acc_i64(&[32767, -32768], 1, &mut z);
    assert_eq!(z, vec![2_147_418_120, -2_147_418_105, 65_542]);
}

/// One SIMD-vs-scalar GEMM parity case: a `(rows, k) x (k, n)` product on
/// an extremes-heavy operand distribution.
#[derive(Debug)]
struct GemmCase {
    rows: usize,
    k: usize,
    n: usize,
    w: Vec<i16>,
    x: Vec<i16>,
}

/// Extremes-heavy i16 draw: rails, alternating-sign rails (the worst case
/// for the `madd` pair sums, including the `(-32768)^2` wrap edge), zero,
/// and full-range random values.
fn extreme_i16(d: &mut prop::Draw, i: usize) -> i16 {
    match d.usize_in(0, 5) {
        0 => i16::MAX,
        1 => i16::MIN,
        2 => {
            if i % 2 == 0 {
                i16::MAX
            } else {
                i16::MIN
            }
        }
        3 => 0,
        _ => d.usize_in(0, u16::MAX as usize) as u16 as i16,
    }
}

#[test]
fn prop_simd_gemm_reduction_bitwise_equals_scalar_at_i16_extremes() {
    // Tentpole guard: the dispatched kernel (AVX2 `madd` when available)
    // and the scalar register-blocked kernel must both equal the naive
    // triple loop BITWISE — no tolerances — at i16 extremes, across ragged
    // panel tails (n % 16), row remainders (rows % RB), and odd k (the
    // zero-padded `madd` pair). On machines without AVX2, or under
    // GWLSTM_FORCE_SCALAR=1, the dispatch arm degenerates to
    // scalar-vs-scalar; ci.sh runs this suite once per dispatch arm so
    // both kernels are exercised wherever the hardware allows.
    prop::check_with(
        prop::Config {
            cases: 48,
            ..Default::default()
        },
        "simd-i16-gemm-bitwise-parity",
        |d| {
            let rows = d.usize_in(1, 9); // crosses RB=4 and SIMD RB=2 remainders
            let k = d.usize_in(1, 24); // odd k exercises the zero-padded pair
            let n = d.usize_in(1, 48); // ragged tails + multiple full panels
            let w: Vec<i16> = (0..k * n).map(|i| extreme_i16(d, i)).collect();
            let x: Vec<i16> = (0..rows * k).map(|i| extreme_i16(d, i)).collect();
            GemmCase { rows, k, n, w, x }
        },
        |c| {
            let m = PackedMatrixI16::pack(&c.w, c.k, c.n);
            // nonzero init: gemm ACCUMULATES into z
            let mut z_dispatch = vec![-3i64; c.rows * c.n];
            let mut z_scalar = vec![-3i64; c.rows * c.n];
            m.gemm_acc_i64(&c.x, c.rows, &mut z_dispatch);
            m.gemm_acc_i64_scalar(&c.x, c.rows, &mut z_scalar);
            let mut want = vec![-3i64; c.rows * c.n];
            for r in 0..c.rows {
                for kk in 0..c.k {
                    for j in 0..c.n {
                        want[r * c.n + j] +=
                            c.x[r * c.k + kk] as i64 * c.w[kk * c.n + j] as i64;
                    }
                }
            }
            if z_dispatch != want {
                return Err(format!(
                    "dispatched kernel diverged from naive at rows={} k={} n={}",
                    c.rows, c.k, c.n
                ));
            }
            if z_scalar != want {
                return Err(format!(
                    "scalar kernel diverged from naive at rows={} k={} n={}",
                    c.rows, c.k, c.n
                ));
            }
            Ok(())
        },
    );
}

#[test]
fn quantized_router_health_sweep_catches_nan_input_without_mirror() {
    // Mirror-free health: the quantized tier's post-call sweep reads the
    // integer state (saturation check) + score finiteness, never the f32
    // mirror. A NaN input window quantizes into the integer datapath (to 0
    // — integers cannot hold a NaN) but the MSE score against the raw
    // window is NaN, so the quarantine path must still fire exactly as it
    // did when the sweep read the mirror.
    let w = AutoencoderWeights::synthetic(0xFB, "small");
    let exe =
        ModelExecutor::native_from_weights_policy(&w, "fixed_health", 8, MathPolicy::Quantized);
    let cfg = StreamConfig {
        hop: 8,
        ..Default::default()
    };
    let mut router = StreamRouter::new(&exe, cfg).unwrap();
    router.ingest(1, &[0.25f32; 8], 0);
    let out = router.dispatch(&exe, 0).unwrap();
    assert_eq!(out.len(), 1);
    assert!(
        !out[0].quarantined && out[0].score.is_finite(),
        "healthy chunk must serve"
    );
    let mut poison = [0.25f32; 8];
    poison[3] = f32::NAN;
    router.ingest(1, &poison, 1);
    let out = router.dispatch(&exe, 1).unwrap();
    assert_eq!(out.len(), 1);
    assert!(
        out[0].quarantined,
        "NaN input must still quarantine on the quantized tier"
    );
    assert!(out[0].score.is_nan(), "quarantined window reports NaN");
    // the session recovers: the next clean chunk serves again
    router.ingest(1, &[0.25f32; 8], 100);
    let out = router.dispatch(&exe, 100).unwrap();
    assert!(out.iter().all(|s| !s.quarantined), "recovery after backoff");
}
