//! Fault-tolerance contracts: corrupted input and crashing compute must
//! never take the service down, never leak a window from the conservation
//! ledger, and never perturb a healthy session's output.
//!
//! Contracts pinned here (the acceptance criteria of the fault tentpole):
//!
//! 1. **Isolation** — a NaN/Inf burst in one session's chunk never changes
//!    any other session's scores, bit-for-bit, in both math tiers at
//!    engine threads ∈ {1, 4}. The lockstep batch shares weight
//!    traversals, never operands.
//! 2. **Recovery** — a quarantined session resumes producing finite
//!    scores after its backoff, restored from its last-good checkpoint
//!    (or zeros if none exists yet).
//! 3. **Supervision** — a panicking engine call is caught, the engine is
//!    warm-restarted, and the next tick scores bit-identically to a run
//!    in which the poisoned tick never happened; a panic storm escalates
//!    to a clean shutdown with the ledger intact.
//! 4. **Campaign** — a seeded chaos plan (NaN bursts + stalls + misframed
//!    chunks + scheduled panics across 100 sessions) completes without
//!    crashing and attributes every produced window to exactly one of
//!    {served, dropped, quarantined}.

use gwlstm::config::ServeConfig;
use gwlstm::coordinator::ingress::PreparedTick;
use gwlstm::coordinator::{
    run_serving_streaming, FaultSpec, StreamRouter, TickOutcome, TickPipeline,
};
use gwlstm::model::{AutoencoderWeights, MathPolicy};
use gwlstm::runtime::ModelExecutor;
use gwlstm::stream::{SessionHealth, StreamConfig};
use gwlstm::util::prop;
use gwlstm::util::rng::Rng;

/// Deterministic clean chunk for (session, tick).
fn clean_chunk(seed: u64, session: u64, tick: u64, hop: usize) -> Vec<f32> {
    let mut rng = Rng::new(seed ^ session.wrapping_mul(0x9E37_79B9) ^ tick.wrapping_mul(0xB5));
    (0..hop).map(|_| rng.gaussian() as f32).collect()
}

/// One randomized isolation scenario.
#[derive(Debug)]
struct IsolationCase {
    seed: u64,
    hop: usize,
    victim: u64,
    fault_tick: u64,
}

#[test]
fn prop_nan_burst_never_perturbs_other_sessions() {
    // Contract 1: the victim's poisoned row must not move a single bit of
    // any neighbor's score — in both tiers, single- and multi-threaded.
    let w = AutoencoderWeights::synthetic(0xFA17, "small");
    const SESSIONS: u64 = 3;
    // enough ticks that the victim's 1-tick quarantine backoff always ends
    // with room to score again (fault_tick <= 3 -> ready again by tick 5)
    const TICKS: u64 = 7;
    prop::check_with(
        prop::Config {
            cases: 4, // each case runs 2 tiers x 2 thread counts x 4 routers
            ..Default::default()
        },
        "nan-burst-isolation",
        |d| IsolationCase {
            seed: d.usize_in(1, 1 << 20) as u64,
            hop: d.usize_in(4, 8),
            victim: d.usize_in(0, SESSIONS as usize - 1) as u64,
            fault_tick: d.usize_in(1, 3) as u64,
        },
        |case| {
            for policy in [MathPolicy::BitExact, MathPolicy::FastSimd] {
                for threads in [1usize, 4] {
                    let exe = ModelExecutor::native_from_weights_policy_threads(
                        &w, "iso", case.hop, policy, threads,
                    );
                    let cfg = StreamConfig {
                        hop: case.hop,
                        ..Default::default()
                    };
                    let mut shared = StreamRouter::new(&exe, cfg).map_err(|e| e.to_string())?;
                    let mut solos: Vec<StreamRouter> = (0..SESSIONS)
                        .map(|_| StreamRouter::new(&exe, cfg))
                        .collect::<Result<_, _>>()
                        .map_err(|e| e.to_string())?;
                    let mut victim_recovered = false;
                    for tick in 0..TICKS {
                        for s in 0..SESSIONS {
                            let mut chunk = clean_chunk(case.seed, s, tick, case.hop);
                            if s == case.victim && tick == case.fault_tick {
                                // poison straight past the DQ gate: the
                                // finiteness sweep is the last line
                                chunk[case.hop / 2] = f32::NAN;
                                chunk[0] = f32::INFINITY;
                            } else {
                                // solo twins see only clean traffic
                                solos[s as usize].ingest(s, &chunk, tick);
                            }
                            shared.ingest(s, &chunk, tick);
                        }
                        let got = shared.dispatch(&exe, tick).map_err(|e| e.to_string())?;
                        for sc in &got {
                            if sc.stream == case.victim {
                                if tick > case.fault_tick && !sc.quarantined {
                                    victim_recovered = sc.score.is_finite();
                                }
                                continue;
                            }
                            let want = solos[sc.stream as usize]
                                .dispatch(&exe, tick)
                                .map_err(|e| e.to_string())?;
                            let w0 = want.first().ok_or("solo produced nothing")?;
                            if w0.score.to_bits() != sc.score.to_bits() {
                                return Err(format!(
                                    "{policy:?} t{threads} tick {tick}: neighbor {} \
                                     perturbed ({} != {})",
                                    sc.stream, sc.score, w0.score
                                ));
                            }
                        }
                    }
                    let stats = shared.fault_stats();
                    if stats.quarantine_events == 0 {
                        return Err("poisoned row never quarantined".into());
                    }
                    if !victim_recovered {
                        return Err("victim never resumed finite scores".into());
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn quarantined_session_recovers_through_backoff_both_tiers() {
    // Contract 2 at the integration level: poison -> quarantine -> backoff
    // holds the session out -> clean chunks score finite again and health
    // returns to Healthy.
    let hop = 6usize;
    let w = AutoencoderWeights::synthetic(0xFA18, "small");
    for policy in [MathPolicy::BitExact, MathPolicy::FastSimd] {
        let exe =
            ModelExecutor::native_from_weights_policy_threads(&w, "recover", hop, policy, 1);
        let cfg = StreamConfig {
            hop,
            snapshot_ticks: 1, // checkpoint every tick: recovery restores it
            ..Default::default()
        };
        let mut router = StreamRouter::new(&exe, cfg).unwrap();
        // two clean ticks (builds a last-good checkpoint), then poison
        for tick in 0..2u64 {
            router.ingest(9, &clean_chunk(5, 9, tick, hop), tick);
            let out = router.dispatch(&exe, tick).unwrap();
            assert!(out[0].score.is_finite(), "{policy:?}: clean tick not finite");
        }
        let mut bad = clean_chunk(5, 9, 2, hop);
        bad[1] = f32::NEG_INFINITY;
        router.ingest(9, &bad, 2);
        let out = router.dispatch(&exe, 2).unwrap();
        assert!(out[0].quarantined, "{policy:?}: poison not quarantined");
        assert_eq!(
            router.registry().get(9).unwrap().health,
            SessionHealth::Quarantined
        );
        // backoff after the first quarantine is 1 tick; feed clean chunks
        // until the session scores again
        let mut resumed = false;
        for tick in 3..8u64 {
            router.ingest(9, &clean_chunk(5, 9, tick, hop), tick);
            for sc in router.dispatch(&exe, tick).unwrap() {
                assert!(!sc.quarantined, "{policy:?}: clean chunk re-quarantined");
                assert!(sc.score.is_finite());
                resumed = true;
            }
        }
        assert!(resumed, "{policy:?}: session never resumed after backoff");
        assert_eq!(
            router.registry().get(9).unwrap().health,
            SessionHealth::Healthy
        );
        let stats = router.fault_stats();
        assert_eq!(stats.quarantine_events, 1);
        assert_eq!(
            stats.recovered_snapshot, 1,
            "{policy:?}: with snapshot_ticks=1 recovery must restore the checkpoint"
        );
    }
}

#[test]
fn supervised_pipeline_survives_scheduled_panic_bitexactly() {
    // Contract 3: tick 1's engine call panics (chaos-scheduled); the
    // supervisor rebuilds the engine and tick 2 scores exactly as if the
    // panicked tick's chunk had never been fed (state was never scattered).
    let hop = 5usize;
    let w = AutoencoderWeights::synthetic(0xFA19, "small");
    let chunks: Vec<Vec<f32>> = (0..3).map(|t| clean_chunk(11, 1, t, hop)).collect();

    // serial reference: feed chunk 0 and chunk 2 only
    let exe = ModelExecutor::native_from_weights(&w, "sup_ref", hop);
    let cfg = StreamConfig {
        hop,
        ..Default::default()
    };
    let mut reference = StreamRouter::new(&exe, cfg).unwrap();
    reference.ingest(1, &chunks[0], 0);
    let want0 = reference.dispatch(&exe, 0).unwrap()[0].score;
    reference.ingest(1, &chunks[2], 1);
    let want2 = reference.dispatch(&exe, 1).unwrap()[0].score;

    // supervised pipeline: all three chunks, engine call 1 panics
    let wf = w.clone();
    let sched = FaultSpec::parse("panic@1").unwrap().panic_schedule();
    let (mut pipe, info) = TickPipeline::spawn_supervised(
        move || Ok(ModelExecutor::native_from_weights(&wf, "sup", hop)),
        sched,
    )
    .unwrap();
    let mut router = StreamRouter::from_proto(info.proto, cfg);
    let mut flat = Vec::new();
    let mut group = None;
    let mut got = Vec::new();
    for (tick, chunk) in chunks.iter().enumerate() {
        let tick = tick as u64;
        router.ingest(1, chunk, tick);
        let ids = router.take_ready(&mut flat, tick);
        assert_eq!(ids.len(), 1);
        router.gather_group(&ids, &mut group);
        pipe.submit(PreparedTick {
            ids,
            flat: std::mem::take(&mut flat),
            group: group.take().unwrap(),
            tick,
        })
        .unwrap();
        match pipe.wait().unwrap() {
            TickOutcome::Done(fin) => {
                got.extend(router.complete(&fin.ids, &fin.scores, &fin.group, fin.tick));
                flat = fin.flat;
                group = Some(fin.group);
            }
            TickOutcome::Panicked(fail) => {
                assert_eq!(tick, 1, "only call 1 is scheduled to panic");
                assert!(!fail.escalated, "one panic must not escalate");
                assert_eq!(fail.restarts, 1);
                router.mark_suspect(&fail.ids);
                flat = fail.flat;
                group = Some(fail.group);
            }
        }
    }
    assert_eq!(got.len(), 2, "ticks 0 and 2 scored, tick 1 lost");
    assert_eq!(got[0].score.to_bits(), want0.to_bits());
    assert_eq!(
        got[1].score.to_bits(),
        want2.to_bits(),
        "post-restart tick must score as if the panicked tick never happened"
    );
    assert_eq!(
        router.registry().get(1).unwrap().health,
        SessionHealth::Healthy,
        "a finite post-restart score clears Suspect"
    );
}

fn chaos_cfg(sessions: usize, max_windows: usize, spec: &str) -> ServeConfig {
    ServeConfig {
        model: "chaos".into(),
        calib_windows: 8,
        max_windows,
        inject_prob: 0.3,
        stream_sessions: sessions,
        stream_hop: 8,
        streaming: true,
        ingress: true,
        faults: Some(FaultSpec::parse(spec).unwrap()),
        ..Default::default()
    }
}

#[test]
fn seeded_chaos_campaign_survives_and_conserves() {
    // Contract 4: NaN bursts + stalls + misframed chunks across 100
    // sessions plus scheduled engine panics (one inside calibration, one
    // while serving). The run must complete and the ledger must balance.
    let weights = AutoencoderWeights::synthetic(0xD0E, "small");
    let cfg = chaos_cfg(
        100,
        400,
        "seed=7,nan=0.05,stall=0.02,stall_us=50,badlen=0.03,panic@6,panic@10",
    );
    let report = run_serving_streaming(&weights, &cfg).unwrap();
    assert!(report.platform.contains("ingress"));
    assert_eq!(
        report.ingested,
        report.windows as u64 + report.dropped + report.quarantined,
        "ledger violated: ingested {} != served {} + dropped {} + quarantined {}",
        report.ingested,
        report.windows,
        report.dropped,
        report.quarantined
    );
    assert_eq!(report.sheds.total(), report.dropped, "shed classes must sum");
    assert!(report.quarantined > 0, "5% NaN + 3% badlen must gate something");
    assert!(report.engine_panics >= 1, "a scheduled panic must have fired");
    assert!(report.windows > 0, "the campaign must still serve");
    // quarantine refusals carry no detector output, so every SERVED score
    // came from a clean lockstep row
    assert!(report.auc > 0.0 && report.auc <= 1.0);
}

#[test]
fn engine_panic_storm_escalates_to_clean_shutdown() {
    // Contract 3b: panics on every engine call past calibration. After
    // MAX_ENGINE_RESTARTS consecutive restarts the supervisor gives up;
    // the leader must shut down cleanly with the ledger still balanced.
    let weights = AutoencoderWeights::synthetic(0xD0E, "small");
    let spec: Vec<String> = (8..40).map(|k| format!("panic@{k}")).collect();
    let cfg = chaos_cfg(4, 64, &format!("seed=3,{}", spec.join(",")));
    let report = run_serving_streaming(&weights, &cfg).unwrap();
    assert!(
        report.engine_panics > gwlstm::coordinator::ingress::MAX_ENGINE_RESTARTS,
        "storm must exhaust the restart budget (got {} panics)",
        report.engine_panics
    );
    assert_eq!(
        report.ingested,
        report.windows as u64 + report.dropped + report.quarantined,
        "escalated shutdown leaked windows"
    );
    assert_eq!(report.sheds.total(), report.dropped);
}

#[test]
fn fault_free_ingress_run_reports_no_fault_activity() {
    // The fault-tolerance layer must be invisible when nothing is
    // injected: no quarantines, no panics, no recoveries — and the PR 5
    // conservation identity degenerates back to ingested == served +
    // dropped.
    let weights = AutoencoderWeights::synthetic(0xD0E, "small");
    let cfg = ServeConfig {
        model: "clean".into(),
        calib_windows: 8,
        max_windows: 48,
        stream_sessions: 3,
        stream_hop: 8,
        streaming: true,
        ingress: true,
        ..Default::default()
    };
    let report = run_serving_streaming(&weights, &cfg).unwrap();
    assert_eq!(report.quarantined, 0);
    assert_eq!(report.engine_panics, 0);
    assert_eq!(report.recovered, 0);
    assert_eq!(report.ingested, report.windows as u64 + report.dropped);
}
