//! Integration: the full serving coordinator over the live synthetic
//! stream. The native batched backend runs everywhere; the PJRT sections
//! require artifacts and skip gracefully otherwise.

use std::time::{Duration, Instant};

use gwlstm::config::{Manifest, ServeConfig};
use gwlstm::coordinator::batcher::Batcher;
use gwlstm::coordinator::{run_serving, run_serving_native, run_serving_with_policy, Policy};
use gwlstm::gw::dataset::{make_dataset, DEFAULT_SNR};
use gwlstm::model::{score_f32, AutoencoderWeights};
use gwlstm::runtime::ModelExecutor;

fn manifest() -> Option<Manifest> {
    Manifest::load("artifacts").ok()
}

fn small_cfg(windows: usize) -> ServeConfig {
    ServeConfig {
        model: "small_ts8".into(),
        calib_windows: 48,
        max_windows: windows,
        inject_prob: 0.4,
        ..Default::default()
    }
}

#[test]
fn serves_all_windows_and_reports() {
    let Some(m) = manifest() else {
        eprintln!("skipping: run `make artifacts`");
        return;
    };
    let report = run_serving(&m, &small_cfg(120)).unwrap();
    assert_eq!(report.windows, 120);
    assert_eq!(report.dropped, 0, "no backpressure expected at this rate");
    assert!(report.infer.n >= 120);
    assert!(report.infer.p50_ns > 0.0);
    assert!(report.throughput_per_s > 0.0);
    // labels flow through: the summary must have both classes
    assert!(report.summary.true_pos + report.summary.false_neg > 0);
    assert!(report.summary.true_neg + report.summary.false_pos > 0);
}

#[test]
fn fpr_calibration_respected_on_served_traffic() {
    let Some(m) = manifest() else {
        eprintln!("skipping: run `make artifacts`");
        return;
    };
    let mut cfg = small_cfg(400);
    cfg.target_fpr = 0.05;
    cfg.calib_windows = 128;
    let report = run_serving(&m, &cfg).unwrap();
    // served FPR within a loose statistical band of the target
    let fpr = report.summary.fpr();
    assert!(fpr <= 0.18, "served FPR {fpr} vs target 0.05");
}

#[test]
fn detection_quality_on_stream() {
    let Some(m) = manifest() else {
        eprintln!("skipping: run `make artifacts`");
        return;
    };
    // the nominal TS=100 model on its native window size
    let cfg = ServeConfig {
        model: "nominal_ts100".into(),
        calib_windows: 32,
        max_windows: 80,
        inject_prob: 0.5,
        ..Default::default()
    };
    let report = run_serving(&m, &cfg).unwrap();
    assert!(report.auc > 0.85, "stream AUC {}", report.auc);
}

#[test]
fn microbatch_policy_serves_everything_too() {
    let Some(m) = manifest() else {
        eprintln!("skipping: run `make artifacts`");
        return;
    };
    let report = run_serving_with_policy(
        &m,
        &small_cfg(90),
        Policy::MicroBatch {
            max_batch: 8,
            max_wait: std::time::Duration::from_millis(2),
        },
    )
    .unwrap();
    assert_eq!(report.windows, 90);
}

#[test]
fn two_workers_complete() {
    let Some(m) = manifest() else {
        eprintln!("skipping: run `make artifacts`");
        return;
    };
    let mut cfg = small_cfg(100);
    cfg.workers = 2;
    let report = run_serving(&m, &cfg).unwrap();
    assert_eq!(report.windows, 100);
}

// ---------------------------------------------------------------------------
// Native batched backend: no artifacts needed, so these always execute.
// ---------------------------------------------------------------------------

fn native_cfg(windows: usize) -> ServeConfig {
    ServeConfig {
        model: "small_native".into(),
        calib_windows: 48,
        max_windows: windows,
        inject_prob: 0.4,
        // deep enough that backpressure is structurally impossible for the
        // window counts below — the no-drop asserts are then deterministic
        queue_depth: 512,
        ..Default::default()
    }
}

#[test]
fn native_backend_serves_all_windows_batch1() {
    let w = AutoencoderWeights::synthetic(0xAB, "small");
    let report = run_serving_native(&w, 8, &native_cfg(150), Policy::Immediate).unwrap();
    assert_eq!(report.windows, 150);
    assert_eq!(report.dropped, 0);
    assert_eq!(report.platform, "native-batched");
    assert!(report.infer.n >= 150);
    assert!(report.throughput_per_s > 0.0);
    // batch-1 policy: every dispatch is a singleton micro-batch
    assert_eq!(report.batches, 150);
    assert!((report.mean_batch - 1.0).abs() < 1e-9);
    // labels flow through: the summary must have both classes
    assert!(report.summary.true_pos + report.summary.false_neg > 0);
    assert!(report.summary.true_neg + report.summary.false_pos > 0);
}

#[test]
fn native_microbatch_dispatches_whole_batches_through_engine() {
    let w = AutoencoderWeights::synthetic(0xCD, "small");
    let report = run_serving_native(
        &w,
        8,
        &native_cfg(240),
        Policy::MicroBatch {
            max_batch: 8,
            max_wait: Duration::from_millis(5),
        },
    )
    .unwrap();
    assert_eq!(report.windows, 240, "every admitted window scored");
    assert_eq!(report.dropped, 0, "no window shed at this depth");
    // The MicroBatch drain reaches the engine as whole batches, not an
    // internal batch-1 loop: strictly fewer dispatches than windows, and
    // at least ceil(240 / 8) of them.
    assert!(
        report.batches < 240,
        "expected multi-window dispatches, got {} singleton batches",
        report.batches
    );
    assert!(report.batches >= 240 / 8, "batches {} too few", report.batches);
    assert!(
        report.mean_batch > 1.5 && report.mean_batch <= 8.0,
        "mean batch {} outside (1.5, 8]",
        report.mean_batch
    );
}

#[test]
fn native_two_workers_complete() {
    let w = AutoencoderWeights::synthetic(0xEF, "small");
    let mut cfg = native_cfg(160);
    cfg.workers = 2;
    let report = run_serving_native(
        &w,
        8,
        &cfg,
        Policy::MicroBatch {
            max_batch: 4,
            max_wait: Duration::from_millis(2),
        },
    )
    .unwrap();
    assert_eq!(report.windows, 160);
    assert_eq!(report.dropped, 0);
}

#[test]
fn microbatch_drain_scores_match_scalar_reference() {
    // The coordinator contract in miniature, deterministically: windows
    // from the dataset twin drain through the batcher as micro-batches and
    // each batch is scored by ONE batched-engine call; results must match
    // the scalar per-window reference and preserve FIFO order.
    let ts = 8;
    let w = AutoencoderWeights::synthetic(0x77, "small");
    let exe = ModelExecutor::native_from_weights(&w, "small_native", ts);
    let events = make_dataset(0xD15, 12, ts, DEFAULT_SNR);
    let far = Duration::from_secs(3600);
    let mut batcher = Batcher::new(Policy::MicroBatch {
        max_batch: 4,
        max_wait: far,
    });
    let mut scored: Vec<f32> = Vec::new();
    let drain = |batcher: &mut Batcher<Vec<f32>>, now: Instant, out: &mut Vec<f32>| {
        while let Some(batch) = batcher.take_ready(now) {
            assert!(batch.len() <= 4, "batch over max_batch");
            let mut flat = Vec::with_capacity(batch.len() * ts);
            for p in &batch {
                flat.extend_from_slice(&p.item);
            }
            out.extend(exe.score_batch(&flat, batch.len()).unwrap());
        }
    };
    for e in &events {
        batcher.push(e.samples.clone());
        drain(&mut batcher, Instant::now(), &mut scored);
    }
    drain(&mut batcher, Instant::now() + far + far, &mut scored);
    assert_eq!(scored.len(), events.len(), "windows lost in the drain");
    for (i, e) in events.iter().enumerate() {
        let reference = score_f32(&w, &e.samples);
        let got = scored[i];
        assert!(
            (got - reference).abs() <= 1e-5,
            "window {i}: batched {got} vs scalar {reference}"
        );
    }
}
