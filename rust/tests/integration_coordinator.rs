//! Integration: the full serving coordinator over the live synthetic
//! stream (artifacts required; skips gracefully otherwise).

use gwlstm::config::{Manifest, ServeConfig};
use gwlstm::coordinator::{run_serving, run_serving_with_policy, Policy};

fn manifest() -> Option<Manifest> {
    Manifest::load("artifacts").ok()
}

fn small_cfg(windows: usize) -> ServeConfig {
    ServeConfig {
        model: "small_ts8".into(),
        calib_windows: 48,
        max_windows: windows,
        inject_prob: 0.4,
        ..Default::default()
    }
}

#[test]
fn serves_all_windows_and_reports() {
    let Some(m) = manifest() else {
        eprintln!("skipping: run `make artifacts`");
        return;
    };
    let report = run_serving(&m, &small_cfg(120)).unwrap();
    assert_eq!(report.windows, 120);
    assert_eq!(report.dropped, 0, "no backpressure expected at this rate");
    assert!(report.infer.n >= 120);
    assert!(report.infer.p50_ns > 0.0);
    assert!(report.throughput_per_s > 0.0);
    // labels flow through: the summary must have both classes
    assert!(report.summary.true_pos + report.summary.false_neg > 0);
    assert!(report.summary.true_neg + report.summary.false_pos > 0);
}

#[test]
fn fpr_calibration_respected_on_served_traffic() {
    let Some(m) = manifest() else {
        eprintln!("skipping: run `make artifacts`");
        return;
    };
    let mut cfg = small_cfg(400);
    cfg.target_fpr = 0.05;
    cfg.calib_windows = 128;
    let report = run_serving(&m, &cfg).unwrap();
    // served FPR within a loose statistical band of the target
    let fpr = report.summary.fpr();
    assert!(fpr <= 0.18, "served FPR {fpr} vs target 0.05");
}

#[test]
fn detection_quality_on_stream() {
    let Some(m) = manifest() else {
        eprintln!("skipping: run `make artifacts`");
        return;
    };
    // the nominal TS=100 model on its native window size
    let cfg = ServeConfig {
        model: "nominal_ts100".into(),
        calib_windows: 32,
        max_windows: 80,
        inject_prob: 0.5,
        ..Default::default()
    };
    let report = run_serving(&m, &cfg).unwrap();
    assert!(report.auc > 0.85, "stream AUC {}", report.auc);
}

#[test]
fn microbatch_policy_serves_everything_too() {
    let Some(m) = manifest() else {
        eprintln!("skipping: run `make artifacts`");
        return;
    };
    let report = run_serving_with_policy(
        &m,
        &small_cfg(90),
        Policy::MicroBatch {
            max_batch: 8,
            max_wait: std::time::Duration::from_millis(2),
        },
    )
    .unwrap();
    assert_eq!(report.windows, 90);
}

#[test]
fn two_workers_complete() {
    let Some(m) = manifest() else {
        eprintln!("skipping: run `make artifacts`");
        return;
    };
    let mut cfg = small_cfg(100);
    cfg.workers = 2;
    let report = run_serving(&m, &cfg).unwrap();
    assert_eq!(report.windows, 100);
}
