//! Property-based tests (via the in-tree `util::prop` framework — proptest
//! itself is not vendored in this offline image) over the system's core
//! invariants: DSE/perf-model algebra, Pareto dominance, simulator
//! causality, router/batcher behaviour, ROC/AUC laws, fixed-point bounds.

use gwlstm::coordinator::batcher::{Batcher, Policy};
use gwlstm::coordinator::router::{Job, RouteResult, Router};
use gwlstm::eval::roc::{auc, calibrate_threshold};
use gwlstm::hls::device::{Device, DEVICES};
use gwlstm::hls::dse::{balance_layer, partition_model};
use gwlstm::hls::pareto::{balanced_family, frontier, naive_family};
use gwlstm::hls::perf_model::{layer_perf, model_perf, DesignPoint, LayerDims};
use gwlstm::model::act_lut::SigmoidLut;
use gwlstm::model::fixed::{q16_to_f32, to_q16, FixedLstm};
use gwlstm::model::weights::LstmWeights;
use gwlstm::model::{forward_f32, forward_f32_batch, AutoencoderWeights};
use gwlstm::sim::{simulate, SimConfig};
use gwlstm::util::prop::{check, Draw};
use gwlstm::util::rng::Rng;

fn any_device(d: &mut Draw) -> &'static Device {
    &DEVICES[d.usize_in(0, DEVICES.len() - 1)]
}

fn any_dims(d: &mut Draw) -> LayerDims {
    LayerDims::new(d.usize_in(1, 64) as u32, d.usize_in(1, 64) as u32)
}

#[test]
fn prop_eq3_dsp_cost_monotone_in_reuse() {
    // Increasing either reuse factor never increases DSP cost.
    check(
        "dsp-monotone-in-reuse",
        |d| {
            let dev = any_device(d);
            let dims = any_dims(d);
            let rx = d.usize_in(1, 20) as u32;
            let rh = d.usize_in(1, 20) as u32;
            (dev, dims, rx, rh)
        },
        |&(dev, dims, rx, rh)| {
            let base = layer_perf(dev, dims, rx, rh, 8).dsp_total();
            let more_rx = layer_perf(dev, dims, rx + 1, rh, 8).dsp_total();
            let more_rh = layer_perf(dev, dims, rx, rh + 1, 8).dsp_total();
            if more_rx <= base && more_rh <= base {
                Ok(())
            } else {
                Err(format!("base {base}, rx+1 {more_rx}, rh+1 {more_rh}"))
            }
        },
    );
}

#[test]
fn prop_eq1_layer_ii_scales_with_ts() {
    check(
        "layer-ii-linear-in-ts",
        |d| {
            let dev = any_device(d);
            let dims = any_dims(d);
            let rh = d.usize_in(1, 10) as u32;
            let ts = d.usize_in(1, 64) as u32;
            (dev, dims, rh, ts)
        },
        |&(dev, dims, rh, ts)| {
            let a = layer_perf(dev, dims, 1, rh, ts);
            let b = layer_perf(dev, dims, 1, rh, 2 * ts);
            if b.ii_layer == 2 * a.ii_layer {
                Ok(())
            } else {
                Err(format!("{} vs 2x{}", b.ii_layer, a.ii_layer))
            }
        },
    );
}

#[test]
fn prop_balanced_choice_satisfies_eq7_and_same_ii() {
    check(
        "balanced-eq7",
        |d| {
            let dev = any_device(d);
            let dims = any_dims(d);
            let rh = d.usize_in(1, 16) as u32;
            (dev, dims, rh)
        },
        |&(dev, dims, rh)| {
            let c = balance_layer(dev, dims, rh, 8);
            if c.rx != rh + dev.lt_sigma + dev.lt_tail {
                return Err(format!("rx {} violates Eq. 7", c.rx));
            }
            // balanced rx never dominates the loop: ii set by the recurrence
            let expect_ii = dev.lt_mult + (rh - 1) + dev.lt_sigma + dev.lt_tail;
            if c.ii != expect_ii {
                return Err(format!("ii {} vs {}", c.ii, expect_ii));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_dse_fits_budget_and_monotone() {
    check(
        "dse-budget",
        |d| {
            let dev = any_device(d);
            let n_layers = d.usize_in(1, 4);
            let layers: Vec<LayerDims> = (0..n_layers).map(|_| any_dims(d)).collect();
            let budget = d.usize_in(50, 20_000) as u64;
            (dev, layers, budget)
        },
        |(dev, layers, budget)| {
            let p = partition_model(dev, layers, 8, 1, *budget);
            if p.feasible && p.perf.dsp_model > *budget {
                return Err(format!("used {} > budget {budget}", p.perf.dsp_model));
            }
            // doubling the budget can only improve (or keep) the II
            let p2 = partition_model(dev, layers, 8, 1, budget * 2);
            if p.feasible && p2.feasible && p2.perf.ii_sys > p.perf.ii_sys {
                return Err("more budget made II worse".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_pareto_frontier_nondominated() {
    check(
        "pareto-nondominated",
        |d| {
            let dev = any_device(d);
            let dims = any_dims(d);
            let r_max = d.usize_in(2, 12) as u32;
            (dev, dims, r_max)
        },
        |&(dev, dims, r_max)| {
            let mut pts = naive_family(dev, dims, 1, r_max);
            pts.extend(balanced_family(dev, dims, 1, r_max));
            let f = frontier(&pts);
            for a in &f {
                for b in &f {
                    if (b.dsp < a.dsp && b.ii <= a.ii) || (b.dsp <= a.dsp && b.ii < a.ii) {
                        return Err(format!("{a:?} dominated by {b:?}"));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_sim_steady_ii_equals_model() {
    check(
        "sim-matches-model",
        |d| {
            let dev = any_device(d);
            let rh = d.usize_in(1, 6) as u32;
            let rx = d.usize_in(1, 18) as u32;
            let small = d.bool();
            (dev, rx, rh, small)
        },
        |&(dev, rx, rh, small)| {
            let point = if small {
                DesignPoint::small_autoencoder(rx, rh, 8)
            } else {
                DesignPoint::nominal_autoencoder(rx, rh, 8)
            };
            let m = model_perf(dev, &point);
            let s = simulate(&SimConfig {
                point,
                device: *dev,
                inferences: 40,
                arrival_interval: None,
                rewind: true,
                overlap: true,
            });
            if (s.steady_ii - m.ii_sys as f64).abs() <= 1.0 {
                Ok(())
            } else {
                Err(format!("sim {} vs model {}", s.steady_ii, m.ii_sys))
            }
        },
    );
}

#[test]
fn prop_sim_completions_monotone_and_causal() {
    check(
        "sim-causality",
        |d| {
            let rx = d.usize_in(1, 12) as u32;
            let rh = d.usize_in(1, 6) as u32;
            let interval = if d.bool() {
                None
            } else {
                Some(d.usize_in(1, 400) as u64)
            };
            (rx, rh, interval)
        },
        |&(rx, rh, interval)| {
            let dev = Device::by_name("zynq7045").unwrap();
            let s = simulate(&SimConfig {
                point: DesignPoint::small_autoencoder(rx, rh, 8),
                device: *dev,
                inferences: 12,
                arrival_interval: interval,
                rewind: true,
                overlap: true,
            });
            for (k, w) in s.completions.windows(2).enumerate() {
                if w[1] < w[0] {
                    return Err(format!("completion order violated at {k}"));
                }
            }
            for (k, &l) in s.latencies.iter().enumerate() {
                let arrival = interval.map_or(0, |iv| iv * k as u64);
                if s.completions[k] != arrival + l {
                    return Err("latency bookkeeping broken".into());
                }
                if l == 0 {
                    return Err("zero-latency inference".into());
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_router_conserves_jobs() {
    check(
        "router-conservation",
        |d| {
            let workers = d.usize_in(1, 4);
            let depth = d.usize_in(1, 8);
            let jobs = d.usize_in(0, 40);
            (workers, depth, jobs)
        },
        |&(workers, depth, jobs)| {
            let (router, queues) = Router::new(workers, depth);
            let mut sent = 0usize;
            let mut shed = 0usize;
            for seq in 0..jobs as u64 {
                match router.route(Job { seq, payload: seq }) {
                    RouteResult::Sent(_) => sent += 1,
                    RouteResult::Backpressure => shed += 1,
                    RouteResult::Closed => return Err("closed unexpectedly".into()),
                }
            }
            router.shutdown();
            let mut received = 0usize;
            for q in &queues {
                while q.recv().is_some() {
                    received += 1;
                }
            }
            if sent != received {
                return Err(format!("sent {sent} != received {received}"));
            }
            if sent + shed != jobs {
                return Err("job accounting leak".into());
            }
            // capacity law: backpressure only once all queues are full
            if shed > 0 && sent < workers * depth {
                return Err(format!("shed with spare capacity: sent {sent} < {}", workers * depth));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_batcher_never_loses_or_reorders() {
    check(
        "batcher-fifo",
        |d| {
            let policy = if d.bool() {
                Policy::Immediate
            } else {
                Policy::MicroBatch {
                    max_batch: d.usize_in(1, 6),
                    max_wait: std::time::Duration::from_secs(0), // always flush
                }
            };
            let items = d.vec(32, |dd| dd.usize_in(0, 1000));
            (policy, items)
        },
        |(policy, items)| {
            let mut b = Batcher::new(*policy);
            let mut out = Vec::new();
            for &it in items {
                b.push(it);
                while let Some(batch) = b.take_ready(std::time::Instant::now()) {
                    out.extend(batch.into_iter().map(|p| p.item));
                }
            }
            while let Some(batch) = b.take_ready(std::time::Instant::now()) {
                out.extend(batch.into_iter().map(|p| p.item));
            }
            if &out != items {
                return Err(format!("order/loss: {out:?} vs {items:?}"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_microbatch_dispatch_no_loss_no_reorder() {
    // The batched coordinator path: windows from several streams are
    // interleaved into the batcher, drained as micro-batches, and routed as
    // whole jobs. Invariants: no window is lost, order within each stream
    // is preserved end-to-end (single worker => FIFO), and no dispatched
    // micro-batch ever exceeds max_batch.
    check(
        "microbatch-dispatch",
        |d| {
            let n_streams = d.usize_in(1, 4);
            let per_stream = d.usize_in(0, 12);
            let max_batch = d.usize_in(1, 6);
            (n_streams, per_stream, max_batch)
        },
        |&(n_streams, per_stream, max_batch)| {
            let far = std::time::Duration::from_secs(3600);
            let mut batcher = Batcher::new(Policy::MicroBatch {
                max_batch,
                max_wait: far,
            });
            // queue deep enough that backpressure is structurally impossible
            let total = n_streams * per_stream;
            let (router, queues) = Router::<Vec<(usize, usize)>>::new(1, total.max(1));
            let route_batch = |items: Vec<(usize, usize)>| -> Result<(), String> {
                if items.len() > max_batch {
                    return Err(format!("batch {} > max_batch {max_batch}", items.len()));
                }
                match router.route(Job {
                    seq: items[0].1 as u64,
                    payload: items,
                }) {
                    RouteResult::Sent(_) => Ok(()),
                    other => Err(format!("unexpected route result {other:?}")),
                }
            };
            // interleave streams round-robin, draining after every push
            for idx in 0..per_stream {
                for stream in 0..n_streams {
                    batcher.push((stream, idx));
                    if let Some(batch) = batcher.take_ready(std::time::Instant::now()) {
                        route_batch(batch.into_iter().map(|p| p.item).collect())?;
                    }
                }
            }
            // final flush (the producer's shutdown drain)
            loop {
                let later = std::time::Instant::now() + far + far;
                match batcher.take_ready(later) {
                    Some(batch) => route_batch(batch.into_iter().map(|p| p.item).collect())?,
                    None => break,
                }
            }
            router.shutdown();
            let mut next_expected = vec![0usize; n_streams];
            let mut received = 0usize;
            while let Some(job) = queues[0].recv() {
                for (stream, idx) in job.payload {
                    if idx != next_expected[stream] {
                        return Err(format!(
                            "stream {stream}: got idx {idx}, expected {}",
                            next_expected[stream]
                        ));
                    }
                    next_expected[stream] += 1;
                    received += 1;
                }
            }
            if received != total {
                return Err(format!("lost windows: {received} of {total}"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_batched_forward_matches_scalar() {
    // Lockstep engine == B independent scalar forwards (1e-5 acceptance
    // bound; the engine actually promises bit-exactness).
    check(
        "batched-forward-parity",
        |d| {
            let seed = d.usize_in(0, 10_000) as u64;
            let batch = d.usize_in(1, 6);
            let ts = d.usize_in(2, 12);
            (seed, batch, ts)
        },
        |&(seed, batch, ts)| {
            let w = AutoencoderWeights::synthetic(seed, "small");
            let mut rng = Rng::new(seed ^ 0xFEED);
            let windows: Vec<f32> = (0..batch * ts).map(|_| rng.gaussian() as f32).collect();
            let got = forward_f32_batch(&w, &windows, batch);
            for b in 0..batch {
                let one = forward_f32(&w, &windows[b * ts..(b + 1) * ts]);
                for (j, (x, y)) in got[b * ts..(b + 1) * ts].iter().zip(&one).enumerate() {
                    if (x - y).abs() > 1e-5 {
                        return Err(format!(
                            "stream {b} sample {j}: batched {x} vs scalar {y}"
                        ));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_batched_fixed_outputs_within_q16_bounds() {
    // The lockstep fixed-point path keeps |h| inside the Q6.10 grid's
    // tanh*sigmoid range (<= 1 + LUT slack) for any input, including
    // saturated extremes, and matches the sequential runs bit-for-bit.
    check(
        "batched-fixed-q16-bounds",
        |d| {
            let seed = d.usize_in(0, 10_000) as u64;
            let lx = d.usize_in(1, 3);
            let lh = d.usize_in(1, 8);
            let batch = d.usize_in(1, 5);
            let ts = d.usize_in(1, 10);
            let extreme = d.bool();
            (seed, lx, lh, batch, ts, extreme)
        },
        |&(seed, lx, lh, batch, ts, extreme)| {
            let mut rng = Rng::new(seed);
            let mut gen = |n: usize, s: f64| -> Vec<f32> {
                (0..n).map(|_| (rng.gaussian() * s) as f32).collect()
            };
            let w = LstmWeights {
                name: "prop".into(),
                lx,
                lh,
                wx: gen(lx * 4 * lh, 0.4),
                wh: gen(lh * 4 * lh, 0.3),
                b: gen(4 * lh, 0.2),
            };
            let f = FixedLstm::from_weights(&w);
            let lut = SigmoidLut::default();
            let xs: Vec<i16> = if extreme {
                (0..batch * ts * lx)
                    .map(|i| if i % 2 == 0 { i16::MAX } else { i16::MIN })
                    .collect()
            } else {
                (0..batch * ts * lx)
                    .map(|_| to_q16(rng.gaussian() as f32))
                    .collect()
            };
            let got = f.run_batch(&lut, &xs, batch, ts);
            if let Some(&v) = got.iter().find(|v| v.unsigned_abs() > 1100) {
                return Err(format!("|h| escaped Q16 bound: {v}"));
            }
            for b in 0..batch {
                let one = f.run(&lut, &xs[b * ts * lx..(b + 1) * ts * lx], ts);
                if got[b * ts * lh..(b + 1) * ts * lh] != one[..] {
                    return Err(format!("stream {b} diverged from sequential run"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_auc_invariances() {
    check(
        "auc-laws",
        |d| {
            let n = d.usize_in(4, 60);
            let scores: Vec<f64> = (0..n).map(|_| d.f64_in(-3.0, 3.0)).collect();
            let labels: Vec<u8> = (0..n).map(|_| d.bool() as u8).collect();
            (scores, labels)
        },
        |(scores, labels)| {
            let has_both = labels.contains(&0) && labels.contains(&1);
            if !has_both {
                return Ok(());
            }
            let a = auc(scores, labels);
            if !(0.0..=1.0).contains(&a) {
                return Err(format!("AUC {a} out of range"));
            }
            // monotone transform invariance
            let warped: Vec<f64> = scores.iter().map(|s| s.exp()).collect();
            let aw = auc(&warped, labels);
            if (a - aw).abs() > 1e-9 {
                return Err(format!("not rank-invariant: {a} vs {aw}"));
            }
            // label flip symmetry: AUC -> 1 - AUC
            let flipped: Vec<u8> = labels.iter().map(|&l| 1 - l).collect();
            let af = auc(scores, &flipped);
            if (a + af - 1.0).abs() > 1e-9 {
                return Err(format!("flip law broken: {a} + {af}"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_threshold_calibration_bound() {
    check(
        "calibration-bound",
        |d| {
            let n = d.usize_in(10, 400);
            let scores: Vec<f64> = (0..n).map(|_| d.f64_in(0.0, 10.0)).collect();
            let fpr = d.f64_in(0.0, 0.5);
            (scores, fpr)
        },
        |(scores, fpr)| {
            let th = calibrate_threshold(scores, *fpr);
            let actual = scores.iter().filter(|&&s| s >= th).count() as f64 / scores.len() as f64;
            // conservative calibration: actual FPR <= target + one sample
            if actual <= fpr + 1.0 / scores.len() as f64 + 1e-9 {
                Ok(())
            } else {
                Err(format!("actual {actual} > target {fpr}"))
            }
        },
    );
}

#[test]
fn prop_q16_roundtrip_error_bounded() {
    check(
        "q16-roundtrip",
        |d| d.f64_in(-31.0, 31.0) as f32,
        |&x| {
            let q = q16_to_f32(to_q16(x));
            if (q - x).abs() <= 0.5 / 1024.0 + 1e-6 {
                Ok(())
            } else {
                Err(format!("{x} -> {q}"))
            }
        },
    );
}
