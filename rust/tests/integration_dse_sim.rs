//! Integration: analytical model (Eqs. 1-7) x DSE x cycle simulator.
//!
//! The paper's performance claims rest on the analytical model; the
//! simulator executes the same designs independently. These tests sweep
//! the whole design grid and require the two to agree.

use gwlstm::hls::device::Device;
use gwlstm::hls::dse::{balanced_rx, min_ii, partition_model};
use gwlstm::hls::perf_model::{model_perf, DesignPoint, LayerDims};
use gwlstm::sim::{simulate, simulate_single_engine, SimConfig, SingleEngineConfig};

fn nominal_layers() -> Vec<LayerDims> {
    vec![
        LayerDims::new(1, 32),
        LayerDims::new(32, 8),
        LayerDims::new(8, 8),
        LayerDims::new(8, 32),
    ]
}

#[test]
fn model_vs_sim_full_grid() {
    // Every (device, arch, rx, rh) combination: steady-state II from the
    // simulator must equal the analytical II_sys (Eq. 1 + Eq. 2).
    for dev_name in ["zynq7045", "u250"] {
        let dev = Device::by_name(dev_name).unwrap();
        for (mk, label) in [
            (DesignPoint::small_autoencoder as fn(u32, u32, u32) -> DesignPoint, "small"),
            (DesignPoint::nominal_autoencoder as fn(u32, u32, u32) -> DesignPoint, "nominal"),
        ] {
            for rh in 1..=6u32 {
                for rx in [1u32, 2, 4, 9, 12, 17] {
                    let point = mk(rx, rh, 8);
                    let m = model_perf(dev, &point);
                    let s = simulate(&SimConfig {
                        point,
                        device: *dev,
                        inferences: 48,
                        arrival_interval: None,
                        rewind: true,
                        overlap: true,
                    });
                    assert!(
                        (s.steady_ii - m.ii_sys as f64).abs() <= 1.0,
                        "{label}@{dev_name} rx={rx} rh={rh}: sim II {} vs model {}",
                        s.steady_ii,
                        m.ii_sys
                    );
                }
            }
        }
    }
}

#[test]
fn sim_latency_within_model_band() {
    // Single-inference latency: simulator vs analytical composition (the
    // model is approximate on overlap slack — keep 15% band).
    for dev_name in ["zynq7045", "u250"] {
        let dev = Device::by_name(dev_name).unwrap();
        for rh in [1u32, 2, 4] {
            let rx = balanced_rx(dev, rh);
            let point = DesignPoint::nominal_autoencoder(rx, rh, 8);
            let m = model_perf(dev, &point);
            let s = simulate(&SimConfig {
                point,
                device: *dev,
                inferences: 1,
                arrival_interval: None,
                rewind: true,
                overlap: true,
            });
            let sim = s.latencies[0] as f64;
            let model = m.latency_cycles as f64;
            assert!(
                (sim - model).abs() / model < 0.15,
                "{dev_name} rh={rh}: sim {sim} vs model {model}"
            );
        }
    }
}

#[test]
fn dse_output_always_fits_and_is_balanced() {
    let u250 = Device::by_name("u250").unwrap();
    for budget in (400..13_000).step_by(317) {
        let p = partition_model(u250, &nominal_layers(), 8, 1, budget as u64);
        if !p.feasible {
            continue;
        }
        assert!(p.perf.dsp_model <= budget as u64, "budget {budget} violated");
        // balanced: all layer IIs equal (the paper's optimal condition)
        let ii0 = p.perf.per_layer[0].ii;
        assert!(p.perf.per_layer.iter().all(|l| l.ii == ii0));
        // Eq. 7 holds per layer
        for c in &p.choices {
            assert_eq!(c.rx, c.rh + u250.lt_sigma + u250.lt_tail);
        }
    }
}

#[test]
fn dse_optimality_no_smaller_ii_fits() {
    // For a few budgets, verify there is no balanced design with smaller II
    // that also fits (the scan is exact, this is the cross-check).
    let u250 = Device::by_name("u250").unwrap();
    for budget in [2_800u64, 5_000, 9_000] {
        let p = partition_model(u250, &nominal_layers(), 8, 1, budget);
        assert!(p.feasible);
        let rh = p.choices[0].rh;
        if rh > 1 {
            let better = DesignPoint::uniform(
                nominal_layers(),
                balanced_rx(u250, rh - 1),
                rh - 1,
                8,
                1,
            );
            let m = model_perf(u250, &better);
            assert!(
                m.dsp_model > budget,
                "budget {budget}: rh={} would fit with smaller II",
                rh - 1
            );
        }
    }
}

#[test]
fn min_ii_is_achieved_with_enough_budget() {
    let u250 = Device::by_name("u250").unwrap();
    let p = partition_model(u250, &nominal_layers(), 8, 1, u250.dsp_total as u64);
    assert!(p.feasible);
    assert_eq!(p.choices[0].ii, min_ii(u250));
}

#[test]
fn table2_headline_dsp_savings() {
    // U1 -> U2: same II, ~2.1k DSPs saved; U2/U3 ratio ~3.3x (Section V-C).
    let u250 = Device::by_name("u250").unwrap();
    let u1 = model_perf(u250, &DesignPoint::nominal_autoencoder(1, 1, 8));
    let u2 = model_perf(u250, &DesignPoint::nominal_autoencoder(9, 1, 8));
    let u3 = model_perf(u250, &DesignPoint::nominal_autoencoder(12, 4, 8));
    assert_eq!(u1.ii_sys, u2.ii_sys);
    assert!(u1.dsp_model - u2.dsp_model >= 1_900);
    assert!((3.0..3.6).contains(&(u2.dsp_model as f64 / u3.dsp_model as f64)));
}

#[test]
fn paper_latency_shape_table4() {
    // Our simulated four-layer latency must sit within ~25% of the paper's
    // 0.867 us (shape, not absolute — different slack modeling).
    let u250 = Device::by_name("u250").unwrap();
    let s = simulate(&SimConfig {
        point: DesignPoint::nominal_autoencoder(9, 1, 8),
        device: *u250,
        inferences: 1,
        arrival_interval: None,
        rewind: true,
        overlap: true,
    });
    let us = u250.cycles_to_us(s.latencies[0]);
    assert!(
        (0.867 - us).abs() / 0.867 < 0.25,
        "four-layer latency {us} vs paper 0.867"
    );
}

#[test]
fn single_engine_starvation_vs_pipeline() {
    // Section I: shared-engine utilization < 1% (Brainwave-scale) on the
    // small model while the layer-pipeline keeps its recurrent units busy.
    let dev = Device::by_name("zynq7045").unwrap();
    let point = DesignPoint::small_autoencoder(9, 1, 8);
    let se = simulate_single_engine(&SingleEngineConfig::default(), &point, dev);
    assert!(se.utilization < 0.01, "single-engine util {}", se.utilization);

    let pipe = simulate(&SimConfig {
        point,
        device: *dev,
        inferences: 64,
        arrival_interval: None,
        rewind: true,
        overlap: true,
    });
    // recurrent units in steady state: occupancy near 100%
    let occ = pipe.units[1].occupancy(pipe.makespan);
    assert!(occ > 0.8, "pipeline recurrent occupancy {occ}");
}

#[test]
fn fig10_sweep_tradeoff_holds_in_sim() {
    // As R_h grows: DSPs fall monotonically, simulated II grows.
    let dev = Device::by_name("zynq7045").unwrap();
    let mut last_dsp = u64::MAX;
    let mut last_ii = 0.0f64;
    for rh in 1..=8u32 {
        let rx = balanced_rx(dev, rh);
        let point = DesignPoint::small_autoencoder(rx, rh, 8);
        let m = model_perf(dev, &point);
        let s = simulate(&SimConfig {
            point,
            device: *dev,
            inferences: 24,
            arrival_interval: None,
            rewind: true,
            overlap: true,
        });
        assert!(m.dsp_model <= last_dsp);
        assert!(s.steady_ii >= last_ii);
        last_dsp = m.dsp_model;
        last_ii = s.steady_ii;
    }
}
