//! Ingress-pipeline parity and conservation: the async front door must
//! change *nothing* numerically and must lose *nothing* silently.
//!
//! Contracts pinned here (the acceptance criteria of the ingress tentpole):
//!
//! 1. **Pipelined parity** — with shedding disabled, the double-buffered
//!    tick pipeline ([`run_pipelined_schedule`]) is bit-identical to the
//!    serial dispatch loop over the same ingest schedule, in both math
//!    tiers, at engine threads ∈ {1, 4}, under ragged schedules (sessions
//!    skipping ticks, multi-hop pushes, late joiners).
//! 2. **Conservation** — every window the producers create is either
//!    scored or counted in exactly one shed class:
//!    `ingested == windows + dropped` and `sheds.total() == dropped`.
//! 3. **SLO property** — conservation holds under randomized bursty
//!    arrivals, queue depths, and SLO budgets; with `slo_us == 0` the SLO
//!    shed class stays empty.
//! 4. **Reject-don't-ignore** — the stateless entry points refuse an
//!    ingress config instead of silently serving without the front door.

use gwlstm::config::ServeConfig;
use gwlstm::coordinator::ingress::run_pipelined_schedule;
use gwlstm::coordinator::{
    run_serving_native, run_serving_streaming, Arrival, Policy, StreamRouter, StreamScore,
};
use gwlstm::model::{AutoencoderWeights, MathPolicy};
use gwlstm::runtime::ModelExecutor;
use gwlstm::stream::StreamConfig;
use gwlstm::util::prop;
use gwlstm::util::rng::Rng;

/// Serial reference: the exact `dispatch()` tick loop over the same
/// schedule, draining the backlog afterwards (one dispatch per remaining
/// ready set) — mirrors `run_pipelined_schedule`'s drive loop minus the
/// pipeline.
fn run_serial_schedule(
    exe: &ModelExecutor,
    cfg: StreamConfig,
    schedule: &[Vec<(u64, Vec<f32>)>],
) -> Vec<StreamScore> {
    let mut router = StreamRouter::new(exe, cfg).unwrap();
    let mut out = Vec::new();
    let mut tick = 0u64;
    let mut feed = schedule.iter();
    loop {
        let fed = match feed.next() {
            Some(items) => {
                for (id, samples) in items {
                    router.ingest(*id, samples, tick);
                }
                true
            }
            None => false,
        };
        let scored = router.dispatch(exe, tick).unwrap();
        let drained = scored.is_empty();
        out.extend(scored);
        if !fed && drained {
            break;
        }
        tick += 1;
    }
    out
}

/// A ragged multi-session schedule: sessions skip ticks, push multiple
/// hops at once (backlog), and join late.
fn ragged_schedule(seed: u64, hop: usize, sessions: usize, ticks: usize) -> Vec<Vec<(u64, Vec<f32>)>> {
    let mut rng = Rng::new(seed);
    let mut schedule = Vec::with_capacity(ticks);
    for t in 0..ticks {
        let mut items = Vec::new();
        for s in 0..sessions {
            if t < s {
                continue; // session s joins at tick s (late joiner)
            }
            if rng.bool(0.3) {
                continue; // skipped tick
            }
            // 1..=3 hops in one push: multi-hop backlog
            let hops = 1 + rng.below(3) as usize;
            let chunk: Vec<f32> = (0..hop * hops).map(|_| rng.gaussian() as f32).collect();
            items.push((s as u64, chunk));
        }
        schedule.push(items);
    }
    schedule
}

#[test]
fn pipelined_schedule_bitidentical_to_serial_loop() {
    // Both math tiers x engine threads {1, 4} x ragged schedules: the
    // pipeline moves call boundaries, never an operand, so equality is
    // exact — not approximate — everywhere.
    let hop = 6usize;
    let w = AutoencoderWeights::synthetic(0x1A61, "small");
    for policy in [MathPolicy::BitExact, MathPolicy::FastSimd] {
        for threads in [1usize, 4] {
            let exe = ModelExecutor::native_from_weights_policy_threads(
                &w, "ingress_ref", hop, policy, threads,
            );
            for seed in [7u64, 8, 9] {
                let schedule = ragged_schedule(seed, hop, 3, 8);
                let cfg = StreamConfig {
                    hop,
                    ..Default::default()
                };
                let want = run_serial_schedule(&exe, cfg, &schedule);
                let wf = w.clone();
                let got = run_pipelined_schedule(
                    move || {
                        Ok(ModelExecutor::native_from_weights_policy_threads(
                            &wf,
                            "ingress_pipe",
                            hop,
                            policy,
                            threads,
                        ))
                    },
                    cfg,
                    &schedule,
                )
                .unwrap();
                assert!(!want.is_empty(), "schedule {seed} produced no work");
                assert_eq!(
                    got, want,
                    "{policy:?} threads={threads} seed={seed}: pipelined diverged from serial"
                );
            }
        }
    }
}

#[test]
fn single_session_pipeline_matches_serial() {
    // Degenerate pipeline (B = 1 every tick): the steady-state ping-pong of
    // the two buffers with no grouping at all.
    let hop = 4usize;
    let w = AutoencoderWeights::synthetic(0x1A62, "small");
    let exe = ModelExecutor::native_from_weights(&w, "ingress_b1", hop);
    let mut rng = Rng::new(11);
    let schedule: Vec<Vec<(u64, Vec<f32>)>> = (0..6)
        .map(|_| {
            vec![(
                5u64,
                (0..hop).map(|_| rng.gaussian() as f32).collect::<Vec<f32>>(),
            )]
        })
        .collect();
    let cfg = StreamConfig {
        hop,
        ..Default::default()
    };
    let want = run_serial_schedule(&exe, cfg, &schedule);
    let wf = w.clone();
    let got = run_pipelined_schedule(
        move || Ok(ModelExecutor::native_from_weights(&wf, "ingress_b1p", hop)),
        cfg,
        &schedule,
    )
    .unwrap();
    assert_eq!(want.len(), 6);
    assert_eq!(got, want);
}

fn ingress_cfg() -> ServeConfig {
    ServeConfig {
        model: "small_ingress".into(),
        calib_windows: 16,
        max_windows: 64,
        inject_prob: 0.4,
        stream_sessions: 3,
        stream_hop: 8,
        streaming: true,
        ingress: true,
        ..Default::default()
    }
}

#[test]
fn ingress_serving_end_to_end_conserves_every_window() {
    let weights = AutoencoderWeights::synthetic(0xD0E, "small");
    let cfg = ingress_cfg();
    let report = run_serving_streaming(&weights, &cfg).unwrap();
    assert!(report.platform.contains("ingress"), "{}", report.platform);
    assert!(report.windows >= cfg.max_windows, "quota not served");
    // conservation: every produced window scored or in exactly one shed class
    assert_eq!(
        report.ingested,
        report.windows as u64 + report.dropped,
        "windows leaked: ingested {} != served {} + dropped {}",
        report.ingested,
        report.windows,
        report.dropped
    );
    assert_eq!(report.sheds.total(), report.dropped, "shed classes must sum");
    assert_eq!(report.sheds.slo, 0, "slo_us = 0 must never SLO-shed");
    assert!(report.auc > 0.0 && report.auc <= 1.0);
    assert!(report.throughput_per_s > 0.0);
    assert!(report.infer.n >= report.windows as u64);
}

#[test]
fn ingress_serving_fast_tier_and_bursty_arrivals() {
    let weights = AutoencoderWeights::synthetic(0xD0E, "small");
    let cfg = ServeConfig {
        math_policy: MathPolicy::FastSimd,
        arrival: Arrival::Bursty,
        slo_us: 50_000,
        ..ingress_cfg()
    };
    let report = run_serving_streaming(&weights, &cfg).unwrap();
    assert!(report.platform.contains("fastsimd"), "{}", report.platform);
    assert_eq!(report.ingested, report.windows as u64 + report.dropped);
    assert_eq!(report.sheds.total(), report.dropped);
}

/// One randomized ingress serving scenario.
#[derive(Debug)]
struct IngressCase {
    sessions: usize,
    hop: usize,
    max_windows: usize,
    queue_depth: usize,
    slo_us: u64,
    bursty: bool,
    pace_us: u64,
}

#[test]
fn prop_ingress_conservation_under_random_arrivals() {
    let weights = AutoencoderWeights::synthetic(0xD0E, "small");
    prop::check_with(
        prop::Config {
            cases: 10, // each case spawns a full serving pipeline
            ..Default::default()
        },
        "ingress-conservation",
        |d| IngressCase {
            sessions: d.usize_in(1, 4),
            hop: d.usize_in(4, 10),
            max_windows: d.usize_in(8, 48),
            queue_depth: d.usize_in(2, 16),
            // 0 (shedding off) or a tight-to-loose budget
            slo_us: if d.bool() { 0 } else { d.usize_in(50, 20_000) as u64 },
            bursty: d.bool(),
            pace_us: if d.bool() { 0 } else { d.usize_in(1, 200) as u64 },
        },
        |case| {
            let cfg = ServeConfig {
                model: "prop_ingress".into(),
                calib_windows: 8,
                max_windows: case.max_windows,
                inject_prob: 0.3,
                stream_sessions: case.sessions,
                stream_hop: case.hop,
                queue_depth: case.queue_depth,
                slo_us: case.slo_us,
                pace_us: case.pace_us,
                arrival: if case.bursty {
                    Arrival::Bursty
                } else {
                    Arrival::Uniform
                },
                streaming: true,
                ingress: true,
                ..Default::default()
            };
            let report = run_serving_streaming(&weights, &cfg).map_err(|e| e.to_string())?;
            if report.ingested != report.windows as u64 + report.dropped {
                return Err(format!(
                    "conservation violated: ingested {} != served {} + dropped {}",
                    report.ingested, report.windows, report.dropped
                ));
            }
            if report.sheds.total() != report.dropped {
                return Err(format!(
                    "shed classes {:?} do not sum to dropped {}",
                    report.sheds, report.dropped
                ));
            }
            if case.slo_us == 0 && report.sheds.slo != 0 {
                return Err(format!(
                    "slo_us = 0 but {} windows SLO-shed",
                    report.sheds.slo
                ));
            }
            if report.windows == 0 {
                return Err("served nothing".into());
            }
            Ok(())
        },
    );
}

#[test]
fn stateless_entry_points_reject_ingress_config() {
    // Reject-don't-ignore: a config asking for the async front door must
    // not silently serve through a pipeline that has no tick to pipeline.
    let weights = AutoencoderWeights::synthetic(0xD0E, "small");
    let cfg = ServeConfig {
        streaming: false,
        ingress: true,
        ..Default::default()
    };
    assert!(run_serving_native(&weights, 8, &cfg, Policy::Immediate).is_err());
}
