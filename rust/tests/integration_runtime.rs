//! Integration: PJRT runtime x AOT artifacts x rust reference models.
//!
//! Requires `make artifacts` (skips gracefully when absent so `cargo test`
//! stays runnable in a fresh checkout).

use gwlstm::config::{load_testset, Manifest};
use gwlstm::eval::auc;
use gwlstm::model::{forward_f32, AutoencoderWeights, FixedAutoencoder};
use gwlstm::runtime::Engine;

fn manifest() -> Option<Manifest> {
    Manifest::load("artifacts").ok()
}

macro_rules! require_artifacts {
    () => {
        match manifest() {
            Some(m) => m,
            None => {
                eprintln!("skipping: run `make artifacts` first");
                return;
            }
        }
    };
}

#[test]
fn all_artifacts_verify_against_oracle() {
    let m = require_artifacts!();
    let engine = Engine::cpu().unwrap();
    for v in &m.variants {
        let exe = engine.load_variant(&m, &v.name).unwrap();
        let err = exe.verify_golden(&m).unwrap();
        assert!(err < 1e-3, "{}: golden max err {err}", v.name);
    }
}

#[test]
fn artifact_matches_rust_reference_model() {
    // The AOT artifact and the pure-rust f32 model run the same trained
    // weights: reconstructions must agree to float tolerance.
    let m = require_artifacts!();
    let engine = Engine::cpu().unwrap();
    let exe = engine.load_variant(&m, "nominal_ts100").unwrap();
    let weights = AutoencoderWeights::load("artifacts/weights_nominal.json").unwrap();
    let (windows, _) = load_testset("artifacts").unwrap();
    for w in windows.iter().take(5) {
        let a = exe.infer(w).unwrap();
        let b = forward_f32(&weights, w);
        let max_err = a
            .iter()
            .zip(&b)
            .map(|(x, y)| (x - y).abs())
            .fold(0.0f32, f32::max);
        assert!(max_err < 1e-3, "PJRT vs rust reference: {max_err}");
    }
}

#[test]
fn small_artifact_matches_small_weights() {
    let m = require_artifacts!();
    let engine = Engine::cpu().unwrap();
    let exe = engine.load_variant(&m, "small_ts8").unwrap();
    let weights = AutoencoderWeights::load("artifacts/weights_small.json").unwrap();
    let win: Vec<f32> = (0..8).map(|i| ((i as f32) / 3.0).sin()).collect();
    let a = exe.infer(&win).unwrap();
    let b = forward_f32(&weights, &win);
    let max_err = a
        .iter()
        .zip(&b)
        .map(|(x, y)| (x - y).abs())
        .fold(0.0f32, f32::max);
    assert!(max_err < 1e-3, "small: PJRT vs reference {max_err}");
}

#[test]
fn served_auc_reproduces_training_auc() {
    // Rust-side AUC over the exported test set must match the python
    // training-side AUC (metrics.json) within a small band.
    let m = require_artifacts!();
    let engine = Engine::cpu().unwrap();
    let exe = engine.load_variant(&m, "nominal_ts100").unwrap();
    let (windows, labels) = load_testset("artifacts").unwrap();
    let scores: Vec<f64> = windows.iter().map(|w| exe.score(w).unwrap() as f64).collect();
    let rust_auc = auc(&scores, &labels);
    let metrics = gwlstm::util::json::Value::from_file("artifacts/metrics.json").unwrap();
    let py_auc = metrics.get("lstm").unwrap().get("auc").unwrap().as_f64().unwrap();
    assert!(
        (rust_auc - py_auc).abs() < 0.02,
        "rust AUC {rust_auc} vs python AUC {py_auc}"
    );
}

#[test]
fn quantized_artifact_close_to_f32_artifact() {
    // Fig. 9 quantization claim through the full AOT path.
    let m = require_artifacts!();
    let engine = Engine::cpu().unwrap();
    let f32_exe = engine.load_variant(&m, "nominal_ts100").unwrap();
    let q16_exe = engine.load_variant(&m, "nominal_ts100_q16").unwrap();
    let (windows, labels) = load_testset("artifacts").unwrap();
    let s_f: Vec<f64> = windows.iter().map(|w| f32_exe.score(w).unwrap() as f64).collect();
    let s_q: Vec<f64> = windows.iter().map(|w| q16_exe.score(w).unwrap() as f64).collect();
    let delta = (auc(&s_f, &labels) - auc(&s_q, &labels)).abs();
    assert!(delta < 0.02, "quantization AUC delta {delta}");
}

#[test]
fn fixed_point_datapath_detects_too() {
    // The bit-level FPGA datapath must preserve detection quality.
    let _ = require_artifacts!();
    let weights = AutoencoderWeights::load("artifacts/weights_nominal.json").unwrap();
    let fixed = FixedAutoencoder::from_weights(&weights);
    let (windows, labels) = load_testset("artifacts").unwrap();
    let n = windows.len().min(120);
    let scores: Vec<f64> = windows[..n].iter().map(|w| fixed.score(w) as f64).collect();
    let a = auc(&scores, &labels[..n]);
    assert!(a > 0.85, "fixed-point AUC {a}");
}

#[test]
fn wrong_input_shape_rejected() {
    let m = require_artifacts!();
    let engine = Engine::cpu().unwrap();
    let exe = engine.load_variant(&m, "small_ts8").unwrap();
    assert!(exe.infer(&[0.0; 7]).is_err());
    assert!(exe.infer(&[0.0; 9]).is_err());
}

#[test]
fn unknown_variant_rejected() {
    let m = require_artifacts!();
    let engine = Engine::cpu().unwrap();
    assert!(engine.load_variant(&m, "does_not_exist").is_err());
}
