//! Streaming-continuation parity: resident `(h, c)` carried across chunks
//! must change *nothing* numerically.
//!
//! Contracts pinned here (the acceptance criteria of the streaming state
//! service):
//!
//! 1. **Chunk parity** — N windows fed chunk-by-chunk through a stateful
//!    session are bit-identical (BitExact tier; and, because chunking does
//!    not reorder any per-element operation, FastSimd too) to ONE
//!    contiguous `run` over the concatenation, at B ∈ {1, 3, 8} and under
//!    ragged hop schedules.
//! 2. **Eviction/recreate** — evicting a session mid-stream and recreating
//!    it restarts from the zero state: the continuation equals a fresh
//!    contiguous run over only the post-recreate samples.
//! 3. **Warm restart** — snapshot + restore is bit-identical to never
//!    having evicted.
//! 4. **Isolation (property)** — interleaved sessions through the
//!    `StreamRouter` never cross states: per-session score sequences match
//!    an isolated-session reference regardless of which other sessions
//!    share each lockstep batch, under randomized interleavings.

use gwlstm::coordinator::{run_serving_native, run_serving_streaming, Policy, StreamRouter};
use gwlstm::config::ServeConfig;
use gwlstm::model::batched::{BatchedLstm, BatchedState};
use gwlstm::model::weights::LstmWeights;
use gwlstm::model::{AutoencoderWeights, MathPolicy, PackedAutoencoder};
use gwlstm::runtime::ModelExecutor;
use gwlstm::stream::StreamConfig;
use gwlstm::util::prop;
use gwlstm::util::rng::Rng;

const BATCHES: [usize; 3] = [1, 3, 8];

fn random_layer(seed: u64, lx: usize, lh: usize) -> LstmWeights {
    let mut rng = Rng::new(seed);
    let mut gen = |n: usize, s: f64| -> Vec<f32> {
        (0..n).map(|_| (rng.gaussian() * s) as f32).collect()
    };
    LstmWeights {
        name: format!("stream_{lx}x{lh}"),
        lx,
        lh,
        wx: gen(lx * 4 * lh, 0.4),
        wh: gen(lh * 4 * lh, 0.3),
        b: gen(4 * lh, 0.1),
    }
}

/// Feed `xs` (batch-major `(B, TS, Lx)`) through `eng` chunk-by-chunk over
/// `hops` (which must sum to TS), carrying state; returns the stitched
/// `(B, TS, Lh)` output.
fn run_chunked(
    eng: &BatchedLstm,
    xs: &[f32],
    batch: usize,
    ts: usize,
    hops: &[usize],
) -> Vec<f32> {
    let (lx, lh) = (eng.w.lx, eng.w.lh);
    assert_eq!(hops.iter().sum::<usize>(), ts, "hop schedule must cover TS");
    let mut st = BatchedState::zeros(batch, lh);
    let mut out = vec![0.0f32; batch * ts * lh];
    let mut t0 = 0usize;
    for &hop in hops {
        let mut chunk = Vec::with_capacity(batch * hop * lx);
        for b in 0..batch {
            chunk.extend_from_slice(&xs[(b * ts + t0) * lx..(b * ts + t0 + hop) * lx]);
        }
        let got = eng.run_stateful(&chunk, batch, hop, &mut st);
        for b in 0..batch {
            out[(b * ts + t0) * lh..(b * ts + t0 + hop) * lh]
                .copy_from_slice(&got[b * hop * lh..(b + 1) * hop * lh]);
        }
        t0 += hop;
    }
    out
}

#[test]
fn chunked_session_bitidentical_to_contiguous_run() {
    // Ragged hop schedules, B ∈ {1, 3, 8}, both math tiers: chunking only
    // moves the call boundary, never an operand or an accumulation order,
    // so equality is exact — not approximate — in BOTH tiers.
    let ts = 24;
    let schedules: [&[usize]; 4] = [&[24], &[1; 24], &[5, 1, 9, 2, 7], &[11, 13]];
    for (seed, (lx, lh)) in [(50u64, (1usize, 9usize)), (51, (3, 8)), (52, (4, 16))] {
        let w = random_layer(seed, lx, lh);
        for policy in [MathPolicy::BitExact, MathPolicy::FastSimd] {
            let eng = BatchedLstm::from_weights_policy(&w, policy);
            for &batch in &BATCHES {
                let mut rng = Rng::new(seed ^ 0x5EED);
                let xs: Vec<f32> = (0..batch * ts * lx)
                    .map(|_| rng.gaussian() as f32)
                    .collect();
                let contiguous = eng.run(&xs, batch, ts);
                for hops in schedules {
                    let chunked = run_chunked(&eng, &xs, batch, ts, hops);
                    assert_eq!(
                        chunked, contiguous,
                        "B={batch} {policy:?} hops={hops:?} diverged"
                    );
                }
            }
        }
    }
}

#[test]
fn autoencoder_session_scores_match_manual_state_threading() {
    // The router + registry + executor stack must produce exactly what
    // direct engine calls with hand-threaded state produce.
    for &batch in &BATCHES {
        let w = AutoencoderWeights::synthetic(60 + batch as u64, "small");
        let exe = ModelExecutor::native_from_weights(&w, "stream_ref", 8);
        let packed = PackedAutoencoder::from_weights(&w);
        let hop = 5usize;
        let cfg = StreamConfig {
            hop,
            ..Default::default()
        };
        let mut router = StreamRouter::new(&exe, cfg).unwrap();
        let mut rng = Rng::new(61);
        let mut states: Vec<_> = (0..batch).map(|_| packed.zero_state(1)).collect();
        for tick in 0..4u64 {
            let chunks: Vec<Vec<f32>> = (0..batch)
                .map(|_| (0..hop).map(|_| rng.gaussian() as f32).collect())
                .collect();
            for (s, chunk) in chunks.iter().enumerate() {
                router.ingest(s as u64, chunk, tick);
            }
            let scored = router.dispatch(&exe, tick).unwrap();
            assert_eq!(scored.len(), batch);
            for (s, chunk) in chunks.iter().enumerate() {
                let want = packed.score_batch_stateful(chunk, 1, &mut states[s]);
                assert_eq!(
                    scored[s].score, want[0],
                    "B={batch} tick={tick} session {s}"
                );
            }
        }
    }
}

#[test]
fn eviction_recreate_mid_stream_restarts_from_zeros() {
    for &batch in &BATCHES {
        let w = AutoencoderWeights::synthetic(70, "small");
        let exe = ModelExecutor::native_from_weights(&w, "stream_evict", 8);
        let packed = PackedAutoencoder::from_weights(&w);
        let hop = 4usize;
        let mut router = StreamRouter::new(
            &exe,
            StreamConfig {
                hop,
                ..Default::default()
            },
        )
        .unwrap();
        let mut rng = Rng::new(71 + batch as u64);
        let chunks: Vec<Vec<f32>> = (0..batch)
            .map(|_| (0..hop).map(|_| rng.gaussian() as f32).collect())
            .collect();
        // two chunks in, then evict session 0 only
        for tick in 0..2u64 {
            for (s, c) in chunks.iter().enumerate() {
                router.ingest(s as u64, c, tick);
            }
            router.dispatch(&exe, tick).unwrap();
        }
        assert!(router.evict(0).is_some());
        // third chunk: session 0 is recreated from zeros, others continue
        for (s, c) in chunks.iter().enumerate() {
            router.ingest(s as u64, c, 2);
        }
        let scored = router.dispatch(&exe, 2).unwrap();
        let mut zero_state = packed.zero_state(1);
        let fresh = packed.score_batch_stateful(&chunks[0], 1, &mut zero_state);
        assert_eq!(
            scored[0].score, fresh[0],
            "B={batch}: recreated session must score like a brand-new stream"
        );
        if batch > 1 {
            // survivors must have 3-chunk continuation state, not zeros
            let mut st = packed.zero_state(1);
            for _ in 0..3 {
                packed.score_batch_stateful(&chunks[1], 1, &mut st);
            }
            let survivor = router.registry().get(1).unwrap();
            assert_eq!(survivor.state.layers[0].h, st.layers[0].h, "survivor h");
            assert_eq!(survivor.state.layers[0].c, st.layers[0].c, "survivor c");
        }
    }
}

#[test]
fn ttl_eviction_then_warm_restart_is_bitexact() {
    let w = AutoencoderWeights::synthetic(80, "small");
    let exe = ModelExecutor::native_from_weights(&w, "stream_ttl", 8);
    let hop = 4usize;
    let cfg = StreamConfig {
        hop,
        ttl_ticks: 2,
        ..Default::default()
    };
    let chunk: Vec<f32> = (0..hop).map(|i| (i as f32 * 0.6).sin()).collect();
    let mut interrupted = StreamRouter::new(&exe, cfg).unwrap();
    let mut reference = StreamRouter::new(&exe, cfg).unwrap();
    // both score one chunk at tick 0
    interrupted.ingest(1, &chunk, 0);
    reference.ingest(1, &chunk, 0);
    assert_eq!(
        interrupted.dispatch(&exe, 0).unwrap(),
        reference.dispatch(&exe, 0).unwrap()
    );
    // TTL fires for the interrupted router only; warm-restart the snapshot
    let evicted = interrupted.evict_expired(10);
    assert_eq!(evicted.len(), 1);
    assert!(interrupted.registry().is_empty());
    interrupted.restore(evicted.into_iter().next().unwrap(), 10);
    // continuation after restore == uninterrupted continuation
    interrupted.ingest(1, &chunk, 11);
    reference.ingest(1, &chunk, 11);
    assert_eq!(
        interrupted.dispatch(&exe, 11).unwrap(),
        reference.dispatch(&exe, 11).unwrap(),
        "warm restart must be bit-identical to an uninterrupted session"
    );
}

/// One randomized interleaving scenario for the isolation property.
#[derive(Debug)]
struct Interleaving {
    hop: usize,
    /// Per-session chunk sequences (session id = index).
    chunks: Vec<Vec<Vec<f32>>>,
    /// Tick schedule: which sessions receive their next chunk this tick.
    schedule: Vec<Vec<usize>>,
}

#[test]
fn prop_interleaved_sessions_never_cross_states() {
    let w = AutoencoderWeights::synthetic(90, "small");
    let exe = ModelExecutor::native_from_weights(&w, "stream_prop", 8);
    prop::check_with(
        prop::Config {
            cases: 24, // each case runs many engine calls; keep the suite fast
            ..Default::default()
        },
        "interleaved-sessions-isolated",
        |d| {
            let hop = d.usize_in(2, 6);
            let n_sessions = d.usize_in(2, 5);
            let chunks: Vec<Vec<Vec<f32>>> = (0..n_sessions)
                .map(|_| {
                    let n_chunks = d.usize_in(1, 4);
                    (0..n_chunks)
                        .map(|_| (0..hop).map(|_| d.f64_in(-2.0, 2.0) as f32).collect())
                        .collect()
                })
                .collect();
            // random arrival order: a shuffled multiset of session ids,
            // partitioned into ticks of random width
            let mut arrivals: Vec<usize> = Vec::new();
            for (s, cs) in chunks.iter().enumerate() {
                arrivals.extend(std::iter::repeat(s).take(cs.len()));
            }
            // Fisher-Yates with the draw's RNG
            for i in (1..arrivals.len()).rev() {
                let j = d.usize_in(0, i);
                arrivals.swap(i, j);
            }
            let mut schedule: Vec<Vec<usize>> = Vec::new();
            while !arrivals.is_empty() {
                // a session appears at most once per tick (one chunk per
                // dispatch); the stable partition keeps per-session order
                let width = d.usize_in(1, arrivals.len().min(n_sessions));
                let mut tick: Vec<usize> = Vec::new();
                let mut remaining: Vec<usize> = Vec::new();
                for &s in &arrivals {
                    if tick.len() < width && !tick.contains(&s) {
                        tick.push(s);
                    } else {
                        remaining.push(s);
                    }
                }
                arrivals = remaining;
                schedule.push(tick);
            }
            Interleaving {
                hop,
                chunks,
                schedule,
            }
        },
        |case| {
            let cfg = StreamConfig {
                hop: case.hop,
                ..Default::default()
            };
            // shared router: sessions interleaved per the schedule
            let mut shared = StreamRouter::new(&exe, cfg).map_err(|e| e.to_string())?;
            let mut got: Vec<Vec<f32>> = vec![Vec::new(); case.chunks.len()];
            let mut next_chunk: Vec<usize> = vec![0; case.chunks.len()];
            for (tick, sessions) in case.schedule.iter().enumerate() {
                for &s in sessions {
                    let c = &case.chunks[s][next_chunk[s]];
                    next_chunk[s] += 1;
                    shared.ingest(s as u64, c, tick as u64);
                }
                for sc in shared.dispatch(&exe, tick as u64).map_err(|e| e.to_string())? {
                    got[sc.stream as usize].push(sc.score);
                }
            }
            // isolated reference: each session alone in its own router
            for (s, cs) in case.chunks.iter().enumerate() {
                let mut solo = StreamRouter::new(&exe, cfg).map_err(|e| e.to_string())?;
                let mut want: Vec<f32> = Vec::new();
                for (tick, c) in cs.iter().enumerate() {
                    solo.ingest(s as u64, c, tick as u64);
                    for sc in solo.dispatch(&exe, tick as u64).map_err(|e| e.to_string())? {
                        want.push(sc.score);
                    }
                }
                if got[s] != want {
                    return Err(format!(
                        "session {s}: grouped scores {:?} != isolated {:?}",
                        got[s], want
                    ));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn streaming_serving_end_to_end() {
    // The full run_serving_streaming loop: serves the quota, scores flow
    // through stateful sessions, AUC is defined, and per-dispatch batches
    // actually group sessions (mean batch ≈ S under full admission).
    let weights = AutoencoderWeights::synthetic(0xD0E, "small");
    let cfg = ServeConfig {
        model: "small_streaming".into(),
        calib_windows: 24,
        max_windows: 96,
        inject_prob: 0.4,
        stream_sessions: 4,
        stream_hop: 8,
        streaming: true,
        ..Default::default()
    };
    let report = run_serving_streaming(&weights, &cfg).unwrap();
    assert_eq!(report.windows, 96);
    assert_eq!(report.dropped, 0);
    assert!(report.platform.contains("streaming"), "{}", report.platform);
    assert!(report.mean_batch > 3.5, "mean batch {}", report.mean_batch);
    assert!(report.auc > 0.0 && report.auc <= 1.0);
    assert!(report.infer.n >= 96);
    assert!(report.throughput_per_s > 0.0);
    // both classes present so the detection summary is meaningful
    assert!(report.summary.true_pos + report.summary.false_neg > 0);
    assert!(report.summary.true_neg + report.summary.false_pos > 0);
}

#[test]
fn stateless_entry_point_rejects_streaming_config() {
    // Reject-don't-ignore: a config asking for resident sessions must not
    // silently serve through the stateless window pipeline.
    let weights = AutoencoderWeights::synthetic(0xD0E, "small");
    let cfg = ServeConfig {
        streaming: true,
        ..Default::default()
    };
    assert!(run_serving_native(&weights, 8, &cfg, Policy::Immediate).is_err());
}

#[test]
fn streaming_serving_fast_tier_runs_and_stays_close() {
    let weights = AutoencoderWeights::synthetic(0xD0E, "small");
    let mk = |policy| ServeConfig {
        model: "small_streaming".into(),
        calib_windows: 16,
        max_windows: 48,
        inject_prob: 0.3,
        stream_sessions: 3,
        stream_hop: 8,
        streaming: true,
        math_policy: policy,
        ..Default::default()
    };
    let exact = run_serving_streaming(&weights, &mk(MathPolicy::BitExact)).unwrap();
    let fast = run_serving_streaming(&weights, &mk(MathPolicy::FastSimd)).unwrap();
    assert_eq!(fast.windows, 48);
    assert!(fast.platform.contains("fastsimd"), "{}", fast.platform);
    // same synthetic feeds, bounded activations: AUC of the two tiers must
    // agree closely (scores drift within FAST_FORWARD_TOL per window)
    assert!(
        (exact.auc - fast.auc).abs() < 0.2,
        "AUC drift {} vs {}",
        exact.auc,
        fast.auc
    );
}
