//! Integration: the rust GW data substrate against the python twin's
//! exported statistics, plus the streaming (IIR) vs batch (FFT) paths.

use gwlstm::gw::dataset::{make_dataset, StrainStream, DECIM, DEFAULT_SNR, FS};
use gwlstm::gw::fft::Plan;
use gwlstm::gw::filter::{Bandpass, Decimator};
use gwlstm::gw::psd::{aligo_psd, colored_noise};
use gwlstm::util::rng::Rng;

#[test]
fn rust_windows_statistically_match_python_export() {
    // The python test set (if built) and rust windows come from the same
    // physics: compare per-window std of sample-to-sample differences — a
    // spectrum-sensitive statistic — between the two generators.
    let Ok((py_windows, py_labels)) = gwlstm::config::load_testset("artifacts") else {
        eprintln!("skipping: run `make artifacts`");
        return;
    };
    let ts = py_windows[0].len();
    let rust_ws = make_dataset(99, 200, ts, DEFAULT_SNR);

    let diff_std = |w: &[f32]| -> f64 {
        let d: Vec<f64> = w.windows(2).map(|p| (p[1] - p[0]) as f64).collect();
        let mu = d.iter().sum::<f64>() / d.len() as f64;
        (d.iter().map(|v| (v - mu) * (v - mu)).sum::<f64>() / d.len() as f64).sqrt()
    };
    let mean_for = |ws: &[Vec<f32>], labels: &[u8], want: u8| -> f64 {
        let sel: Vec<f64> = ws
            .iter()
            .zip(labels)
            .filter(|(_, &l)| l == want)
            .map(|(w, _)| diff_std(w))
            .collect();
        sel.iter().sum::<f64>() / sel.len() as f64
    };
    let py_noise = mean_for(&py_windows, &py_labels, 0);
    let rust_labels: Vec<u8> = rust_ws.iter().map(|w| w.label).collect();
    let rust_vecs: Vec<Vec<f32>> = rust_ws.iter().map(|w| w.samples.clone()).collect();
    let rust_noise = mean_for(&rust_vecs, &rust_labels, 0);
    let ratio = rust_noise / py_noise;
    assert!(
        (0.8..1.25).contains(&ratio),
        "noise diff-std ratio rust/python = {ratio}"
    );
}

#[test]
fn noise_generator_matches_target_psd_in_band() {
    let mut rng = Rng::new(1);
    let n = 4096;
    let plan = Plan::new(n);
    let reps = 6;
    let mut ratio_acc = 0.0;
    let mut count = 0;
    for _ in 0..reps {
        let x = colored_noise(&mut rng, &plan, FS);
        let spec = plan.rfft(&x);
        for (k, c) in spec.iter().enumerate() {
            let f = k as f64 * FS / n as f64;
            if f > 40.0 && f < 300.0 {
                let per = c.abs2() * 2.0 / (FS * n as f64);
                ratio_acc += per / aligo_psd(f);
                count += 1;
            }
        }
    }
    let mean_ratio = ratio_acc / count as f64;
    assert!((0.7..1.4).contains(&mean_ratio), "PSD ratio {mean_ratio}");
}

#[test]
fn streaming_iir_path_approximates_batch_fft_path() {
    // The serving path filters causally (biquads + decimator); the build
    // path brick-walls in frequency. Band-limited energy must agree within
    // filter-rolloff tolerance on the same input.
    let mut rng = Rng::new(7);
    let n = 1 << 14;
    let plan = Plan::new(n);
    let x: Vec<f64> = (0..n).map(|_| rng.gaussian()).collect();

    let batch = gwlstm::gw::psd::bandpass_fd(&x, &plan, FS, 10.0, 128.0);
    let mut bp = Bandpass::butterworth(FS, 10.0, 128.0, 2);
    let stream: Vec<f64> = x.iter().map(|&v| bp.step(v)).collect();

    let energy = |v: &[f64]| v[4096..].iter().map(|s| s * s).sum::<f64>();
    let ratio = energy(&stream) / energy(&batch);
    assert!((0.7..1.3).contains(&ratio), "IIR vs FFT band energy ratio {ratio}");
}

#[test]
fn decimator_matches_stride_sampling_in_band() {
    // For signals already inside the decimated Nyquist, the anti-aliased
    // decimator and plain striding agree closely.
    let n = 1 << 14;
    let f0 = 20.0; // well inside 128 Hz
    let x: Vec<f64> = (0..n)
        .map(|i| (2.0 * std::f64::consts::PI * f0 * i as f64 / FS).sin())
        .collect();
    let mut d = Decimator::new(FS, DECIM);
    let dec: Vec<f64> = x.iter().filter_map(|&v| d.push(v)).collect();
    let strided: Vec<f64> = x.iter().step_by(DECIM).cloned().collect();
    // compare RMS (phase differs due to filter delay)
    let rms = |v: &[f64]| (v[256..].iter().map(|s| s * s).sum::<f64>() / (v.len() - 256) as f64).sqrt();
    let ratio = rms(&dec) / rms(&strided[..dec.len()]);
    assert!((0.85..1.15).contains(&ratio), "decimator rms ratio {ratio}");
}

#[test]
fn stream_and_batch_windows_same_distribution() {
    let ts = 64;
    let mut stream = StrainStream::new(5, ts, DEFAULT_SNR, 0.0);
    let stream_ws: Vec<Vec<f32>> = (0..50).map(|_| stream.next_window().samples).collect();
    let batch_ws = make_dataset(6, 100, ts, DEFAULT_SNR);
    let batch_noise: Vec<&Vec<f32>> = batch_ws
        .iter()
        .filter(|w| w.label == 0)
        .map(|w| &w.samples)
        .collect();
    // both are z-scored; compare lag-1 autocorrelation (structure check)
    let lag1 = |w: &[f32]| -> f64 {
        let n = w.len() - 1;
        (0..n).map(|i| (w[i] * w[i + 1]) as f64).sum::<f64>() / n as f64
    };
    let s_mean = stream_ws.iter().map(|w| lag1(w)).sum::<f64>() / stream_ws.len() as f64;
    let b_mean = batch_noise.iter().map(|w| lag1(w)).sum::<f64>() / batch_noise.len() as f64;
    assert!(
        (s_mean - b_mean).abs() < 0.15,
        "lag-1 autocorr: stream {s_mean} vs batch {b_mean}"
    );
}

#[test]
fn injected_windows_raise_reference_model_scores() {
    // End-of-pipe sanity without artifacts: the *fixed-point* reference
    // model trained... no wait, untrained weights won't separate. Use the
    // trained weights when available; otherwise skip.
    let Ok(weights) = gwlstm::model::AutoencoderWeights::load("artifacts/weights_nominal.json")
    else {
        eprintln!("skipping: run `make artifacts`");
        return;
    };
    let ws = make_dataset(11, 60, 100, DEFAULT_SNR);
    let mut sig = 0.0;
    let mut noi = 0.0;
    let (mut ns, mut nn) = (0, 0);
    for w in &ws {
        let s = gwlstm::model::score_f32(&weights, &w.samples) as f64;
        if w.label == 1 {
            sig += s;
            ns += 1;
        } else {
            noi += s;
            nn += 1;
        }
    }
    assert!(
        sig / ns as f64 > noi / nn as f64,
        "injections should score higher: sig {} vs noise {}",
        sig / ns as f64,
        noi / nn as f64
    );
}
