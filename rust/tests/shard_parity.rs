//! Sharded-tier contracts: splitting the session registry across N shard
//! lanes must change *nothing* numerically and must lose *nothing* from
//! the conservation ledger — including across a mid-run drain/rebalance.
//!
//! Contracts pinned here (the acceptance criteria of the shard tentpole):
//!
//! 1. **Shard parity** — any stream's score sequence under
//!    [`run_sharded_schedule`] at shards ∈ {1, 2, 4} is bit-identical to
//!    the unsharded pipeline ([`run_pipelined_schedule`]) over the same
//!    ingest schedule, in both math tiers, at engine threads ∈ {1, 4}.
//! 2. **Drain bit-exactness** — draining lanes mid-run (snapshot warm
//!    restart onto the survivors) leaves every stream's sequence
//!    bit-identical to never having sharded at all.
//! 3. **Ledger roll-up** — each per-shard ledger conserves on its own
//!    (`ingested == served + dropped + quarantined`) and the field-wise
//!    sum of the per-shard ledgers IS the global ledger, exactly — under
//!    clean runs, capacity-eviction churn, and the seeded chaos plan.
//! 4. **Eviction accounting** — an LRU victim's unconsumed windows land
//!    in the `Evicted` shed class instead of vanishing (the PR 8
//!    `make_room_for` fix), at registry scale (100k churning ids) and
//!    through the sharded serving path.

use std::collections::HashMap;

use gwlstm::config::ServeConfig;
use gwlstm::coordinator::ingress::run_pipelined_schedule;
use gwlstm::coordinator::{
    run_serving_streaming, run_sharded_schedule, shard_of, FaultSpec, StreamScore,
};
use gwlstm::model::batched::{BatchedState, StreamState};
use gwlstm::model::{AutoencoderWeights, MathPolicy};
use gwlstm::runtime::ModelExecutor;
use gwlstm::stream::{SessionRegistry, StreamConfig};
use gwlstm::util::rng::Rng;

/// Per-stream score sequences, bit-cast: scores arrive interleaved across
/// lanes (retire order is per-tick, ascending lane), but within one stream
/// the order is its chunk order — the only order parity can promise.
fn per_stream(scores: &[StreamScore]) -> HashMap<u64, Vec<(u32, bool)>> {
    let mut by: HashMap<u64, Vec<(u32, bool)>> = HashMap::new();
    for s in scores {
        by.entry(s.stream)
            .or_default()
            .push((s.score.to_bits(), s.quarantined));
    }
    by
}

/// A ragged multi-session schedule: sessions skip ticks, push 1–3 whole
/// hops at once (backlog), and join late. Whole hops only — the sharded
/// harness requires it for exact window ledgers.
fn ragged_schedule(seed: u64, hop: usize, sessions: usize, ticks: usize) -> Vec<Vec<(u64, Vec<f32>)>> {
    let mut rng = Rng::new(seed);
    let mut schedule = Vec::with_capacity(ticks);
    for t in 0..ticks {
        let mut items = Vec::new();
        for s in 0..sessions {
            if t < s % 4 {
                continue; // staggered late joiners
            }
            if rng.bool(0.3) {
                continue; // skipped tick
            }
            let hops = 1 + rng.below(3) as usize;
            let chunk: Vec<f32> = (0..hop * hops).map(|_| rng.gaussian() as f32).collect();
            items.push((s as u64, chunk));
        }
        schedule.push(items);
    }
    schedule
}

/// Windows a schedule produces (whole hops by construction).
fn schedule_windows(schedule: &[Vec<(u64, Vec<f32>)>], hop: usize) -> u64 {
    schedule
        .iter()
        .flatten()
        .map(|(_, samples)| (samples.len() / hop) as u64)
        .sum()
}

#[test]
fn sharded_schedule_bitidentical_to_unsharded_pipeline() {
    // Contract 1: shards x threads x math tiers. The lockstep batch shares
    // weight traversals, never operands, and every lane runs an identical
    // engine — so a stream's sequence is invariant under the shard count.
    let hop = 6usize;
    let sessions = 6usize;
    let w = AutoencoderWeights::synthetic(0x54A2D, "small");
    for policy in [MathPolicy::BitExact, MathPolicy::FastSimd] {
        for threads in [1usize, 4] {
            let schedule = ragged_schedule(21, hop, sessions, 10);
            let windows = schedule_windows(&schedule, hop);
            let cfg = StreamConfig {
                hop,
                ..Default::default()
            };
            let factory = ModelExecutor::native_factory(&w, "shard_ref", hop, policy, threads);
            let want = per_stream(&run_pipelined_schedule(factory.clone(), cfg, &schedule).unwrap());
            assert!(!want.is_empty(), "reference produced no work");
            for shards in [1usize, 2, 4] {
                let report =
                    run_sharded_schedule(factory.clone(), cfg, shards, &schedule, &[]).unwrap();
                let got = per_stream(&report.scores);
                assert_eq!(
                    got, want,
                    "{policy:?} threads={threads} shards={shards}: sharded diverged"
                );
                // Contract 3 on the same run: each ledger closes, the sum
                // is the schedule, nothing was shed on a clean run.
                assert_eq!(report.ledgers.len(), shards);
                for l in &report.ledgers {
                    assert!(l.conserved(), "shard {} ledger leaked: {l:?}", l.shard);
                }
                let total = report
                    .ledgers
                    .iter()
                    .fold(gwlstm::coordinator::ShardLedger::default(), |a, l| a.plus(l));
                assert_eq!(total.ingested, windows, "every scheduled window counted");
                assert_eq!(total.served, report.scores.len() as u64);
                assert_eq!(total.quarantined, 0, "clean run");
                assert_eq!(total.dropped(), 0, "clean run sheds nothing");
            }
        }
    }
}

#[test]
fn mid_run_drain_is_bit_exact_and_conserves() {
    // Contract 2: drain two of four lanes mid-schedule. Refugees move via
    // snapshot warm restart; their continuation must be bit-identical to
    // the unsharded run, and the home-shard ledgers must still close.
    let hop = 5usize;
    let sessions = 16usize; // enough ids that every lane homes several
    let w = AutoencoderWeights::synthetic(0xD4A1, "small");
    for policy in [MathPolicy::BitExact, MathPolicy::FastSimd] {
        let schedule = ragged_schedule(33, hop, sessions, 12);
        let windows = schedule_windows(&schedule, hop);
        let cfg = StreamConfig {
            hop,
            ..Default::default()
        };
        let factory = ModelExecutor::native_factory(&w, "drain_ref", hop, policy, 1);
        let want = per_stream(&run_pipelined_schedule(factory.clone(), cfg, &schedule).unwrap());
        // Sanity: the drained lanes actually homed streams, so the drain
        // moved real state instead of vacuously passing.
        assert!(
            (0..sessions as u64).any(|id| shard_of(id, 4) == 1),
            "no stream homed on lane 1 — drain test is vacuous"
        );
        let report =
            run_sharded_schedule(factory, cfg, 4, &schedule, &[(3, 1), (7, 2)]).unwrap();
        let got = per_stream(&report.scores);
        assert_eq!(
            got, want,
            "{policy:?}: drained run diverged from the unsharded pipeline"
        );
        for l in &report.ledgers {
            assert!(l.conserved(), "shard {} ledger leaked: {l:?}", l.shard);
        }
        let total = report
            .ledgers
            .iter()
            .fold(gwlstm::coordinator::ShardLedger::default(), |a, l| a.plus(l));
        assert_eq!(total.ingested, windows);
        assert_eq!(total.served, report.scores.len() as u64);
        assert_eq!(total.dropped(), 0, "default capacity: drains evict no one");
    }
}

#[test]
fn eviction_churn_books_victims_and_conserves() {
    // Contracts 3 + 4: squeeze the per-lane registries so LRU churn fires
    // constantly. Victims' unconsumed windows must land in the Evicted
    // shed class (never vanish), and every per-shard ledger must still
    // close exactly.
    let hop = 4usize;
    let w = AutoencoderWeights::synthetic(0xEC7, "small");
    let cfg = StreamConfig {
        hop,
        max_sessions: 2, // per lane: 24 streams churn hard through 2 slots
        ..Default::default()
    };
    let mut rng = Rng::new(9);
    let schedule: Vec<Vec<(u64, Vec<f32>)>> = (0..10)
        .map(|t| {
            (0..24u64)
                .filter(|s| (s + t) % 3 != 0)
                .map(|s| {
                    // two hops per push: one can dispatch next tick, one
                    // sits pending — so evictions always strand windows
                    let chunk: Vec<f32> =
                        (0..hop * 2).map(|_| rng.gaussian() as f32).collect();
                    (s, chunk)
                })
                .collect()
        })
        .collect();
    let windows = schedule_windows(&schedule, hop);
    let factory = ModelExecutor::native_factory(&w, "churn", hop, MathPolicy::BitExact, 1);
    let report = run_sharded_schedule(factory, cfg, 2, &schedule, &[]).unwrap();
    let total = report
        .ledgers
        .iter()
        .fold(gwlstm::coordinator::ShardLedger::default(), |a, l| a.plus(l));
    assert!(
        total.sheds.evicted > 0,
        "24 streams through 2-slot registries must evict: {total:?}"
    );
    for l in &report.ledgers {
        assert!(l.conserved(), "shard {} ledger leaked under churn: {l:?}", l.shard);
    }
    assert_eq!(
        total.ingested, windows,
        "window count drifted under churn"
    );
    assert_eq!(
        total.ingested,
        total.served + total.dropped() + total.quarantined,
        "global roll-up leaked: {total:?}"
    );
}

#[test]
fn registry_scale_churn_conserves_100k_ids() {
    // Contract 4 at scale, no engine: 100k distinct ids churn through a
    // 64-slot registry, one window each. Every window is either still
    // resident or came back in an eviction victim's snapshot — the
    // `make_room_for` fix means no third bucket exists.
    let hop = 4usize;
    let cfg = StreamConfig {
        hop,
        max_sessions: 64,
        ..Default::default()
    };
    let proto = StreamState {
        batch: 1,
        layers: vec![BatchedState::zeros(1, 2)],
        quant: None,
    };
    let mut reg = SessionRegistry::new(cfg, proto);
    let chunk = vec![0.5f32; hop];
    let mut evicted_windows = 0u64;
    for id in 0..100_000u64 {
        if let Some(victim) = reg.ingest(id, &chunk, id) {
            evicted_windows += (victim.pending.len() / hop) as u64;
        }
    }
    assert_eq!(reg.len(), 64, "registry must sit exactly at capacity");
    let resident_windows: u64 = reg
        .ids()
        .iter()
        .map(|&id| (reg.get(id).unwrap().pending_len() / hop) as u64)
        .sum();
    assert_eq!(
        evicted_windows + resident_windows,
        100_000,
        "windows leaked at scale: {evicted_windows} evicted + {resident_windows} resident"
    );
}

fn sharded_cfg(shards: usize) -> ServeConfig {
    ServeConfig {
        model: "shard_e2e".into(),
        calib_windows: 8,
        max_windows: 96,
        inject_prob: 0.3,
        stream_sessions: 12,
        stream_hop: 8,
        streaming: true,
        ingress: true,
        shards,
        ..Default::default()
    }
}

/// Assert `report.shard_ledgers` each conserve and sum field-wise to the
/// report's global ledger — the roll-up identity of the sharded tier.
fn assert_ledger_rollup(report: &gwlstm::coordinator::ServeReport) {
    assert_eq!(report.shard_ledgers.len(), report.shards);
    for l in &report.shard_ledgers {
        assert!(
            l.conserved(),
            "shard {} ledger leaked: ingested {} != served {} + dropped {} + quarantined {}",
            l.shard,
            l.ingested,
            l.served,
            l.dropped(),
            l.quarantined
        );
    }
    let total = report
        .shard_ledgers
        .iter()
        .fold(gwlstm::coordinator::ShardLedger::default(), |a, l| a.plus(l));
    assert_eq!(total.ingested, report.ingested, "ingested sum drifted");
    assert_eq!(total.served, report.windows as u64, "served sum drifted");
    assert_eq!(total.quarantined, report.quarantined, "quarantine sum drifted");
    assert_eq!(total.dropped(), report.dropped, "dropped sum drifted");
    assert_eq!(
        total.sheds.total(),
        report.sheds.total(),
        "shed breakdown sum drifted"
    );
}

#[test]
fn sharded_serving_end_to_end_conserves() {
    // The full production path at shards = 2: async producers routing to
    // per-shard queues, two supervised lanes, per-home-shard accounting.
    let weights = AutoencoderWeights::synthetic(0xD0E, "small");
    let report = run_serving_streaming(&weights, &sharded_cfg(2)).unwrap();
    assert!(
        report.platform.contains("shard2"),
        "platform must advertise the tier: {}",
        report.platform
    );
    assert_eq!(report.shards, 2);
    assert!(report.windows >= 96, "quota not served");
    assert_eq!(report.quarantined, 0, "clean run");
    assert_ledger_rollup(&report);
}

#[test]
fn sharded_chaos_campaign_conserves_per_shard() {
    // Contract 3 under fire: NaN bursts, stalls, misframed chunks, and a
    // scheduled engine panic, across 2 shard lanes. Every per-shard ledger
    // must close and sum exactly to the global one — fault attribution
    // lands on the home shard no matter which lane was serving.
    let weights = AutoencoderWeights::synthetic(0xD0E, "small");
    let cfg = ServeConfig {
        stream_sessions: 48,
        max_windows: 192,
        faults: Some(
            FaultSpec::parse("seed=11,nan=0.05,stall=0.02,stall_us=50,badlen=0.03,panic@7")
                .unwrap(),
        ),
        ..sharded_cfg(2)
    };
    let report = run_serving_streaming(&weights, &cfg).unwrap();
    assert!(report.platform.contains("shard2"), "{}", report.platform);
    assert!(report.windows > 0, "the campaign must still serve");
    assert!(
        report.quarantined > 0,
        "5% NaN + 3% badlen must gate something"
    );
    assert_ledger_rollup(&report);
}
