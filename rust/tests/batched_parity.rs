//! Golden parity: the batched lockstep engine vs B independent scalar runs.
//!
//! The engine's contract (model::batched module docs) is that every
//! per-element accumulation runs in the same order as the scalar reference,
//! so stream b of a B-batch is *bit-identical* to running stream b alone.
//! The acceptance bound here is the looser 1e-5 max elementwise error from
//! the issue; the asserts additionally report the measured max error so a
//! future kernel change that trades exactness for speed shows its cost.
//!
//! Covered at B ∈ {1, 3, 8}: raw LSTM layers on seeded random weights, the
//! f32 autoencoder on random and on chirp-injected `gw::dataset` windows,
//! the per-stream anomaly scores, and the 16-bit fixed-point datapath
//! (integer MVMs are exact, so that parity is asserted bitwise).

use gwlstm::gw::dataset::{make_dataset, DEFAULT_SNR};
use gwlstm::model::batched::{forward_f32_batch, BatchedLstm, PackedAutoencoder};
use gwlstm::model::lstm::lstm_layer;
use gwlstm::model::weights::LstmWeights;
use gwlstm::model::{forward_f32, score_f32, AutoencoderWeights, FixedAutoencoder};
use gwlstm::util::rng::Rng;

const TOL: f32 = 1e-5;
const BATCHES: [usize; 3] = [1, 3, 8];

fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len(), "length mismatch");
    a.iter()
        .zip(b)
        .map(|(x, y)| (x - y).abs())
        .fold(0.0f32, f32::max)
}

fn random_layer(seed: u64, lx: usize, lh: usize) -> LstmWeights {
    let mut rng = Rng::new(seed);
    let mut gen = |n: usize, s: f64| -> Vec<f32> {
        (0..n).map(|_| (rng.gaussian() * s) as f32).collect()
    };
    LstmWeights {
        name: format!("parity_{lx}x{lh}"),
        lx,
        lh,
        wx: gen(lx * 4 * lh, 0.4),
        wh: gen(lh * 4 * lh, 0.3),
        b: gen(4 * lh, 0.1),
    }
}

#[test]
fn layer_parity_on_seeded_random_weights() {
    for (seed, (lx, lh)) in [(1u64, (1usize, 9usize)), (2, (3, 8)), (3, (8, 32))] {
        let w = random_layer(seed, lx, lh);
        let eng = BatchedLstm::from_weights(&w);
        let ts = 16;
        for &batch in &BATCHES {
            let mut rng = Rng::new(seed ^ 0xD1CE);
            let xs: Vec<f32> = (0..batch * ts * lx)
                .map(|_| rng.gaussian() as f32)
                .collect();
            let got = eng.run(&xs, batch, ts);
            for b in 0..batch {
                let one = lstm_layer(&w, &xs[b * ts * lx..(b + 1) * ts * lx], ts);
                let err = max_abs_diff(&got[b * ts * lh..(b + 1) * ts * lh], &one);
                assert!(
                    err <= TOL,
                    "layer ({lx},{lh}) B={batch} stream {b}: max err {err}"
                );
            }
        }
    }
}

#[test]
fn autoencoder_parity_on_random_windows() {
    for arch in ["small", "nominal"] {
        let w = AutoencoderWeights::synthetic(7, arch);
        let ts = if arch == "small" { 8 } else { 24 };
        for &batch in &BATCHES {
            let mut rng = Rng::new(0xA0 + batch as u64);
            let windows: Vec<f32> = (0..batch * ts).map(|_| rng.gaussian() as f32).collect();
            let got = forward_f32_batch(&w, &windows, batch);
            for b in 0..batch {
                let one = forward_f32(&w, &windows[b * ts..(b + 1) * ts]);
                let err = max_abs_diff(&got[b * ts..(b + 1) * ts], &one);
                assert!(err <= TOL, "{arch} B={batch} stream {b}: max err {err}");
            }
        }
    }
}

#[test]
fn autoencoder_parity_on_chirp_injected_windows() {
    // Real substrate: alternating noise / chirp-injected windows from the
    // dataset twin, through the nominal architecture at its native TS.
    let ts = 100;
    let w = AutoencoderWeights::synthetic(42, "nominal");
    let events = make_dataset(0xC41F, 8, ts, DEFAULT_SNR);
    assert!(events.iter().any(|e| e.label == 1), "need injected windows");
    let flat: Vec<f32> = events.iter().flat_map(|e| e.samples.clone()).collect();
    for &batch in &BATCHES {
        let got = forward_f32_batch(&w, &flat[..batch * ts], batch);
        for b in 0..batch {
            let one = forward_f32(&w, &events[b].samples);
            let err = max_abs_diff(&got[b * ts..(b + 1) * ts], &one);
            assert!(err <= TOL, "chirp B={batch} stream {b}: max err {err}");
        }
    }
}

#[test]
fn score_parity_on_chirp_injected_windows() {
    let ts = 8;
    let w = AutoencoderWeights::synthetic(9, "small");
    let packed = PackedAutoencoder::from_weights(&w);
    let events = make_dataset(0x5C0, 8, ts, DEFAULT_SNR);
    let flat: Vec<f32> = events.iter().flat_map(|e| e.samples.clone()).collect();
    for &batch in &BATCHES {
        let scores = packed.score_batch(&flat[..batch * ts], batch);
        for b in 0..batch {
            let one = score_f32(&w, &events[b].samples);
            let err = (scores[b] - one).abs();
            assert!(err <= TOL, "score B={batch} stream {b}: err {err}");
        }
    }
}

#[test]
fn fixed_datapath_parity_is_bitwise() {
    let ts = 8;
    let w = AutoencoderWeights::synthetic(11, "small");
    let fx = FixedAutoencoder::from_weights(&w);
    let events = make_dataset(0xF1D0, 8, ts, DEFAULT_SNR);
    let flat: Vec<f32> = events.iter().flat_map(|e| e.samples.clone()).collect();
    for &batch in &BATCHES {
        let got = fx.forward_batch(&flat[..batch * ts], batch);
        for b in 0..batch {
            let one = fx.forward(&events[b].samples);
            assert_eq!(
                &got[b * ts..(b + 1) * ts],
                &one[..],
                "fixed B={batch} stream {b} diverged"
            );
        }
    }
}

#[test]
fn f32_batch_parity_is_bitwise_on_one_case() {
    // Stronger than the 1e-5 acceptance bound: the engine promises
    // bit-exactness (same accumulation order); pin it on one case so an
    // order-breaking "optimization" is caught loudly.
    let w = AutoencoderWeights::synthetic(13, "small");
    let ts = 8;
    let mut rng = Rng::new(77);
    let windows: Vec<f32> = (0..3 * ts).map(|_| rng.gaussian() as f32).collect();
    let got = forward_f32_batch(&w, &windows, 3);
    for b in 0..3 {
        let one = forward_f32(&w, &windows[b * ts..(b + 1) * ts]);
        assert_eq!(&got[b * ts..(b + 1) * ts], &one[..], "stream {b}");
    }
}
