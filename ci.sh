#!/usr/bin/env bash
# Repo CI: rust tier-1 (build + tests + bench smoke) and python tests.
#
#   ./ci.sh            run everything available in the environment
#   SKIP_BENCH=1 ./ci.sh   skip the bench smoke pass
#
# The bench smoke pass runs the two perf-tracking bench binaries with tiny
# iteration counts (GWLSTM_BENCH_SMOKE=1) so the bench code cannot silently
# rot between PRs; hotpath also refreshes rust/BENCH_hotpath.json, the
# machine-readable perf baseline.
set -u

cd "$(dirname "$0")"
failures=0

note() { printf '\n=== %s ===\n' "$*"; }

if command -v cargo >/dev/null 2>&1; then
    note "rust: cargo build --release"
    (cd rust && cargo build --release) || failures=$((failures + 1))

    # The suite runs twice: once serial, once with 4-lane engine pools.
    # tests/parallel_parity.rs widens its thread sweep from GWLSTM_THREADS
    # and the serving/e2e tests pick it up via threads_from_env, so the
    # parallel path is exercised suite-wide — results must be bit-identical
    # either way (the model::par contract).
    note "rust: cargo test -q (GWLSTM_THREADS=1)"
    (cd rust && GWLSTM_THREADS=1 cargo test -q) || failures=$((failures + 1))

    note "rust: cargo test -q (GWLSTM_THREADS=4)"
    (cd rust && GWLSTM_THREADS=4 cargo test -q) || failures=$((failures + 1))

    # The quantized suite runs once per SIMD dispatch arm: the default pass
    # above takes the AVX2 madd/FMA kernels where the CPU has them; this
    # pass forces the scalar fallbacks (GWLSTM_FORCE_SCALAR gates both the
    # f32 kloop16 dispatch and the i16 madd dispatch), so both arms of
    # every dispatcher are exercised on any machine — and the quantized
    # outputs must be bitwise identical either way.
    note "rust: cargo test -q --test fixed_parity (GWLSTM_FORCE_SCALAR=1)"
    (cd rust && GWLSTM_FORCE_SCALAR=1 cargo test -q --test fixed_parity) \
        || failures=$((failures + 1))

    # Doc tests + rendered docs are tier-1: every public item in the model/
    # stream layers carries runnable examples (ARCHITECTURE.md points at
    # them), and cargo doc warnings (broken intra-doc links) are errors.
    note "rust: cargo test --doc"
    (cd rust && cargo test -q --doc) || failures=$((failures + 1))

    note "rust: cargo doc --no-deps (RUSTDOCFLAGS=-D warnings)"
    (cd rust && RUSTDOCFLAGS="-D warnings" cargo doc --no-deps --quiet) \
        || failures=$((failures + 1))

    if cargo clippy --version >/dev/null 2>&1; then
        note "rust: cargo clippy -- -D warnings"
        (cd rust && cargo clippy --release --all-targets -- -D warnings) \
            || failures=$((failures + 1))
    else
        echo "WARNING: clippy not installed — lint stage skipped" >&2
    fi

    if [ "${SKIP_BENCH:-0}" != "1" ]; then
        # hotpath runs BOTH math tiers (incl. the streaming stateful-vs-
        # re-encode keys), emits BENCH_hotpath.json +
        # BENCH_hotpath_pr1_baseline.json, and exits nonzero if the
        # FastSimd smoke output diverges from BitExact beyond the
        # model::simd tolerance — a tolerance regression fails CI here.
        # e2e_serving runs in both math tiers via GWLSTM_MATH, which also
        # exercises the streaming serving arm (run_serving_streaming) AND
        # the async-ingress arms (run_serving_ingress, uniform + bursty
        # arrivals — the double-buffered tick pipeline, conservation
        # asserted in-bench) in both tiers; both passes run with 4-lane
        # engine pools (GWLSTM_THREADS) so the thread-sweep serving arm is
        # part of the smoke, and the tier passes merge their tier's keys
        # into rust/BENCH_serving.json. GWLSTM_SHARDS adds the sharded-tier
        # scaling arms (shard/{1,2,4}/* keys) over a 100k-resident-session
        # population — per-shard ledger conservation asserted in-bench.
        # hotpath also emits the par/* thread-scaling keys (parity-guarded:
        # it exits nonzero if any thread count diverges bitwise) and the
        # quant/* fixed-point keys (accuracy-guarded: it exits nonzero if
        # the quantized tier drifts past model::fixed's tolerances). See
        # rust/BENCHMARKS.md.
        note "rust: bench smoke (tiny iteration counts, all three math tiers)"
        (cd rust && GWLSTM_BENCH_SMOKE=1 cargo bench --bench hotpath) \
            || failures=$((failures + 1))
        # quantized runs the SAME serving arms as the f32 tiers — streaming,
        # ingress, thread sweep, and the 2-shard lane — so the Q6.10 engine
        # is exercised through every serving entry point, not just unit
        # tests. The sharded smoke doubles as the "conservation ledger
        # closes under --math quantized" gate from tests/fixed_parity.rs.
        for tier in bitexact fast_simd quantized; do
            (cd rust && GWLSTM_BENCH_SMOKE=1 GWLSTM_MATH="$tier" GWLSTM_THREADS=4 \
                GWLSTM_SHARDS=1,2,4 cargo bench --bench e2e_serving) \
                || failures=$((failures + 1))
        done
    fi

    # Fault-injection smoke: a seeded chaos campaign (NaN bursts, stalls,
    # misframed chunks, one scheduled engine panic) through the ingress
    # pipeline in all three math tiers, across 2 shard lanes. Survival =
    # exit 0; the binary itself asserts the conservation ledger (ingested
    # == served + dropped + quarantined) globally AND per shard (each shard
    # ledger must conserve and the ledgers must sum to the global one),
    # exiting nonzero on a leak. The quantized tier's quarantine sweep runs
    # on the integer state itself (saturation-count health check + score
    # finiteness — the f32 mirror is only refreshed lazily on snapshot
    # paths), so chaos must not behave differently under Q6.10.
    note "rust: fault-injection smoke (seeded chaos campaign, all math tiers, 2 shards)"
    for tier in bitexact fast_simd quantized; do
        (cd rust && cargo run --release --quiet -- serve --native --streaming \
            --ingress --shards 2 --sessions 100 --hop 8 --windows 400 --math "$tier" \
            --faults "seed=7,nan=0.02,stall=0.01,stall_us=100,badlen=0.01,panic@12") \
            || failures=$((failures + 1))
    done
else
    echo "WARNING: cargo not found in PATH — rust tier-1 skipped" >&2
fi

if command -v python >/dev/null 2>&1 && python -c 'import pytest' 2>/dev/null; then
    note "python: pytest python/tests -q"
    python -m pytest python/tests -q || failures=$((failures + 1))
else
    echo "WARNING: python/pytest not available — python tests skipped" >&2
fi

note "ci.sh done: $failures failing stage(s)"
exit "$failures"
