//! Anomaly-detection campaign over the exported python test set: score
//! every event through three datapaths and compare —
//!
//! 1. the AOT artifact on PJRT (what production serves),
//! 2. the pure-rust f32 reference model,
//! 3. the bit-level 16-bit fixed-point datapath (the FPGA numerics).
//!
//! Reproduces the Fig. 9 "quantization is negligible" claim end-to-end in
//! rust and cross-validates all three implementations against each other.
//!
//! ```sh
//! make artifacts && cargo run --release --example anomaly_campaign
//! ```

use gwlstm::config::{load_testset, Manifest};
use gwlstm::eval::auc;
use gwlstm::model::{forward_f32, score_f32, AutoencoderWeights, FixedAutoencoder};
use gwlstm::runtime::Engine;
use gwlstm::util::bench::Table;

fn main() -> gwlstm::Result<()> {
    let (windows, labels) = load_testset("artifacts")?;
    println!("loaded {} test events from artifacts/testset.bin", windows.len());

    // datapath 1: the AOT artifact
    let manifest = Manifest::load("artifacts")?;
    let engine = Engine::cpu()?;
    let exe = engine.load_variant(&manifest, "nominal_ts100")?;

    // datapaths 2+3: rust reference models on the same trained weights
    let weights = AutoencoderWeights::load("artifacts/weights_nominal.json")?;
    let fixed = FixedAutoencoder::from_weights(&weights);

    let mut s_pjrt = Vec::with_capacity(windows.len());
    let mut s_f32 = Vec::with_capacity(windows.len());
    let mut s_q16 = Vec::with_capacity(windows.len());
    let mut max_dev_f32 = 0.0f32; // PJRT vs rust f32 reconstruction deviation
    for w in &windows {
        s_pjrt.push(exe.score(w)? as f64);
        s_f32.push(score_f32(&weights, w) as f64);
        s_q16.push(fixed.score(w) as f64);
        let a = exe.infer(w)?;
        let b = forward_f32(&weights, w);
        for (x, y) in a.iter().zip(&b) {
            max_dev_f32 = max_dev_f32.max((x - y).abs());
        }
    }

    let mut t = Table::new(&["datapath", "AUC", "role"]);
    t.row(&["PJRT artifact (XLA)".into(), format!("{:.4}", auc(&s_pjrt, &labels)), "production serving".into()]);
    t.row(&["rust f32 reference".into(), format!("{:.4}", auc(&s_f32, &labels)), "software oracle".into()]);
    t.row(&["rust Q6.10 fixed-point".into(), format!("{:.4}", auc(&s_q16, &labels)), "FPGA numerics (16-bit)".into()]);
    t.print();

    println!("\nPJRT vs rust-f32 max reconstruction deviation: {max_dev_f32:.3e}");
    let auc_f32 = auc(&s_f32, &labels);
    let auc_q16 = auc(&s_q16, &labels);
    println!(
        "quantization AUC delta (f32 -> q16): {:+.4} (paper: negligible)",
        auc_q16 - auc_f32
    );

    assert!(max_dev_f32 < 1e-3, "PJRT and rust reference diverged");
    assert!((auc_f32 - auc_q16).abs() < 0.05, "quantization broke detection");
    assert!(auc_f32 > 0.8, "reference model lost detection power");
    println!("\nanomaly_campaign OK");
    Ok(())
}
