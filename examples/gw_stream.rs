//! End-to-end validation driver (the system-prompt mandated run): serve a
//! live synthetic LIGO-like stream through the full stack — stream
//! assembly -> router -> PJRT workers executing the AOT LSTM autoencoder ->
//! FPR-calibrated detector — and report latency, throughput and AUC.
//!
//! ```sh
//! make artifacts && cargo run --release --example gw_stream -- [--windows 2000] [--model nominal_ts100]
//! ```
//!
//! The run is recorded in EXPERIMENTS.md §E2E.

use gwlstm::config::{Manifest, ServeConfig};
use gwlstm::coordinator::run_serving;
use gwlstm::util::cli::Args;

fn main() -> gwlstm::Result<()> {
    let args = Args::from_env()?;
    let mut cfg = ServeConfig::default();
    // examples pass flags after `--`; Args handles them uniformly
    if let Some(m) = args.get("model") {
        cfg.model = m.to_string();
    }
    cfg.max_windows = args.usize_or("windows", 2_000)?;
    cfg.workers = args.usize_or("workers", 1)?;
    cfg.inject_prob = args.f64_or("inject-prob", 0.25)?;
    cfg.target_fpr = args.f64_or("fpr", 0.01)?;
    // default: paced admission with headroom over synth+infer time on a
    // single core — the real-time-feed mode; pass --pace-us 0 for stress
    cfg.pace_us = args.usize_or("pace-us", 900)? as u64;
    // shallow queue: a live feed sheds stale windows instead of buffering
    // them (bounded staleness beats unbounded queueing delay)
    cfg.queue_depth = args.usize_or("queue-depth", 2)?;
    args.finish()?;

    println!(
        "serving {} windows of {} (inject_prob={}, target FPR={})\n",
        cfg.max_windows, cfg.model, cfg.inject_prob, cfg.target_fpr
    );
    let manifest = Manifest::load("artifacts")?;
    let report = run_serving(&manifest, &cfg)?;
    report.print();

    // Hard gates: this binary is the repo's e2e health check.
    assert!(report.windows > 0, "no windows served");
    assert!(
        report.auc > 0.8,
        "detection quality collapsed: AUC {}",
        report.auc
    );
    assert!(
        report.summary.fpr() < 5.0 * report.threshold.max(0.05),
        "FPR calibration off"
    );
    println!("\ngw_stream e2e OK");
    Ok(())
}
