//! Quickstart: load an AOT autoencoder artifact, verify it against its
//! golden vector, and score a handful of synthetic strain windows.
//!
//! ```sh
//! make artifacts && cargo run --release --example quickstart
//! ```

use gwlstm::config::Manifest;
use gwlstm::gw::dataset::{StrainStream, DEFAULT_SNR};
use gwlstm::runtime::Engine;

fn main() -> gwlstm::Result<()> {
    // 1. Artifacts are produced once by `make artifacts` (python AOT path);
    //    from here on everything is rust + PJRT.
    let manifest = Manifest::load("artifacts")?;
    let engine = Engine::cpu()?;
    println!("PJRT platform : {}", engine.platform());

    // 2. Compile the small autoencoder (Table II's Z-design model).
    let exe = engine.load_variant(&manifest, "small_ts8")?;
    println!(
        "model         : {} (TS={}, compiled in {:.0} ms)",
        exe.spec.name, exe.spec.ts, exe.compile_ms
    );

    // 3. Numeric check against the jnp oracle's golden vector.
    let err = exe.verify_golden(&manifest)?;
    println!("golden check  : max |err| = {err:.3e}");
    assert!(err < 1e-3, "artifact numerics diverged from the oracle");

    // 4. Score live synthetic strain windows (reconstruction MSE).
    let mut stream = StrainStream::new(42, exe.spec.ts, DEFAULT_SNR, 0.5);
    println!("\nscoring 8 windows from the synthetic detector stream:");
    for _ in 0..8 {
        let w = stream.next_window();
        let t0 = std::time::Instant::now();
        let score = exe.score(&w.samples)?;
        println!(
            "  label={} score={score:>8.5} ({:>6.0} us)",
            w.label,
            t0.elapsed().as_secs_f64() * 1e6
        );
    }
    println!("\nquickstart OK");
    Ok(())
}
