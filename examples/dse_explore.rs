//! Design-space exploration walkthrough: the paper's Section III-B story
//! told by the library — how balancing IIs moves the Pareto frontier and
//! how the DSE algorithm picks reuse factors under shrinking DSP budgets.
//!
//! ```sh
//! cargo run --release --example dse_explore
//! ```

use gwlstm::hls::device::Device;
use gwlstm::hls::dse::{dsp_saving_vs_naive, partition_model};
use gwlstm::hls::pareto::{balanced_family, frontier, max_saving_same_ii, naive_family};
use gwlstm::hls::perf_model::LayerDims;
use gwlstm::sim::{simulate, SimConfig};
use gwlstm::util::bench::Table;

fn main() {
    let zynq = Device::by_name("zynq7045").unwrap();
    let u250 = Device::by_name("u250").unwrap();

    // ---- Fig. 8 narrative: one LSTM(32,32) layer ----
    println!("== Fig. 8: naive vs balanced reuse on an Lx=Lh=32 layer ==\n");
    let dims = LayerDims::new(32, 32);
    let naive = naive_family(zynq, dims, 1, 10);
    let balanced = balanced_family(zynq, dims, 1, 10);
    let mut t = Table::new(&["family", "R_h", "R_x", "DSP", "loop II"]);
    for p in naive.iter().take(3) {
        t.row(&["naive".into(), p.rh.to_string(), p.rx.to_string(), p.dsp.to_string(), p.ii.to_string()]);
    }
    for p in balanced.iter().take(3) {
        t.row(&["balanced".into(), p.rh.to_string(), p.rx.to_string(), p.dsp.to_string(), p.ii.to_string()]);
    }
    t.print();
    println!(
        "\npoint A -> C saving at the same II: {:.0}% fewer DSPs",
        100.0 * (1.0 - balanced[0].dsp as f64 / naive[0].dsp as f64)
    );
    println!(
        "max same-II saving across the sweep: {:.0}%",
        100.0 * max_saving_same_ii(&naive, &balanced)
    );
    let mut all = naive.clone();
    all.extend(balanced.iter().cloned());
    let front = frontier(&all);
    println!(
        "combined frontier has {} points; balanced points on it: {}/{}",
        front.len(),
        front.iter().filter(|p| p.rx != p.rh).count(),
        front.len()
    );

    // ---- the DSE under shrinking budgets (nominal model on U250) ----
    println!("\n== DSE: nominal autoencoder under shrinking DSP budgets (U250) ==\n");
    let layers = vec![
        LayerDims::new(1, 32),
        LayerDims::new(32, 8),
        LayerDims::new(8, 8),
        LayerDims::new(8, 32),
    ];
    let mut t = Table::new(&["budget", "feasible", "R_h", "R_x", "II_sys", "DSPs used", "latency (us)", "sim II"]);
    for budget in [12_288u64, 9_000, 5_000, 2_800, 1_500, 800, 400] {
        let p = partition_model(u250, &layers, 8, 1, budget);
        let sim = simulate(&SimConfig {
            point: p.point.clone(),
            device: *u250,
            inferences: 24,
            arrival_interval: None,
            rewind: true,
            overlap: true,
        });
        t.row(&[
            budget.to_string(),
            if p.feasible { "yes" } else { "NO" }.into(),
            p.choices[0].rh.to_string(),
            p.choices[0].rx.to_string(),
            p.perf.ii_sys.to_string(),
            p.perf.dsp_model.to_string(),
            format!("{:.3}", p.perf.latency_us(u250)),
            format!("{:.1}", sim.steady_ii),
        ]);
    }
    t.print();

    // ---- the headline claim ----
    println!(
        "\nsmall model on Zynq: balanced-II saves {:.0}% DSPs at the same II (paper: up to 42% per layer)",
        100.0 * dsp_saving_vs_naive(zynq, &[LayerDims::new(1, 9), LayerDims::new(9, 9)], 8, 1)
    );
    println!("\ndse_explore OK");
}
